"""Multi-tenant registry (core/tenant.py): correctness of the one-dispatch
cross-tenant Merger, the shared async ingest pool, and one-npz persistence.

The cross-tenant ``query_many`` stacks canonical node sets from *different*
trees into one static-shape merge — the key property is that every answer
is bit-identical to the same query asked of its tenant's store alone (the
padding proofs of core/interval_tree.py apply unchanged, since only the
summary arrays matter), while the whole batch costs one dispatch.
"""
import threading

import numpy as np
import pytest

from repro.core import HistogramStore, TenantRegistry, TelemetryHub

T = 32
BETA = 8
N_PER = 256
PARTS = 6


def _parts(seed, n_parts=PARTS):
    rng = np.random.default_rng(seed)
    return {
        d: rng.gumbel(size=N_PER).astype(np.float32) for d in range(n_parts)
    }


def _registry(n_tenants=6, **kw):
    reg = TenantRegistry(num_buckets=T, **kw)
    for t in range(n_tenants):
        reg.ingest_many(f"svc{t}", _parts(seed=t))
    return reg


# ------------------------------------------------------------ tenant admin
def test_tenant_get_or_create_shares_config():
    reg = TenantRegistry(num_buckets=T, T_node="geometric", cache_size=7)
    s1 = reg.tenant("a")
    assert reg.tenant("a") is s1  # get-or-create is idempotent
    assert s1.num_buckets == T
    assert s1.T_node == "geometric"
    assert s1.cache_size == 7
    assert not s1.async_ingest  # the registry pool owns asynchrony
    assert "a" in reg and "b" not in reg
    with pytest.raises(KeyError):
        reg["b"]
    assert len(reg) == 1 and reg.names() == ["a"]


def test_tenant_names_are_str_normalized():
    """reg.tenant(5) and reg.tenant("5") are the SAME tenant — a non-str
    name must not create a fresh store per call (silently dropping data)."""
    reg = TenantRegistry(num_buckets=T)
    rng = np.random.default_rng(0)
    reg.ingest(5, 0, rng.normal(size=100).astype(np.float32))
    reg.ingest(5, 1, rng.normal(size=100).astype(np.float32))
    assert reg["5"].ids() == [0, 1]  # nothing discarded
    assert reg[5] is reg["5"] and 5 in reg and len(reg) == 1
    h, _ = reg.query(5, 0, 1, BETA)  # int name works end to end
    assert float(np.asarray(h.sizes).sum()) == 200
    reg.ingest_async(5, 2, rng.normal(size=100).astype(np.float32))
    reg.flush()
    assert reg["5"].ids() == [0, 1, 2]  # sync and async share the store
    (r,) = reg.query_many([(5, 0, 2)], BETA)
    assert float(np.asarray(r[0].sizes).sum()) == 300
    reg.close()


# ------------------------------------------- cross-tenant batched queries
def test_query_many_bitexact_vs_per_store_queries():
    """Every answer (histogram AND eps) must be bit-identical to asking
    the tenant's own store — across tenants, window mixes, duplicates."""
    reg = _registry(6)
    rng = np.random.default_rng(99)
    qs = []
    for name in reg.names():
        lo = int(rng.integers(0, PARTS))
        qs.append((name, lo, int(rng.integers(lo, PARTS))))
    qs += [qs[0], ("svc3", 0, PARTS - 1)]  # duplicate + full window
    res = reg.query_many(qs, BETA)
    assert len(res) == len(qs)
    for (name, lo, hi), (h, e) in zip(qs, res):
        h2, e2 = reg[name].query(lo, hi, BETA)
        np.testing.assert_array_equal(
            np.asarray(h.boundaries), np.asarray(h2.boundaries)
        )
        np.testing.assert_array_equal(
            np.asarray(h.sizes), np.asarray(h2.sizes)
        )
        assert e == e2


def test_query_many_is_one_dispatch_and_caches():
    reg = _registry(5)
    qs = [(name, 0, PARTS - 1) for name in reg.names()]
    reg.merge_dispatches = 0
    res = reg.query_many(qs, BETA)
    assert reg.merge_dispatches == 1  # the tentpole claim
    assert len(reg.merge_shapes) == 1
    # warm repeat: zero dispatches, answers from the per-tenant LRUs
    res2 = reg.query_many(qs, BETA)
    assert reg.merge_dispatches == 1
    for (h1, e1), (h2, e2) in zip(res, res2):
        np.testing.assert_array_equal(
            np.asarray(h1.sizes), np.asarray(h2.sizes)
        )
        assert e1 == e2
    # and single-store queries hit the entries query_many populated
    hits0 = reg["svc0"]._tree.cache_hits
    reg["svc0"].query(0, PARTS - 1, BETA)
    assert reg["svc0"]._tree.cache_hits == hits0 + 1


def test_query_many_mixed_hit_miss_single_dispatch():
    reg = _registry(4)
    reg.query_many([("svc0", 0, 2), ("svc1", 1, 3)], BETA)
    d0 = reg.merge_dispatches
    res = reg.query_many(
        [("svc0", 0, 2), ("svc2", 0, 1), ("svc1", 1, 3), ("svc3", 2, 4)],
        BETA,
    )
    assert reg.merge_dispatches == d0 + 1  # one dispatch for the 2 misses
    assert all(h is not None for h, _ in res)


def test_query_many_strict_false_placeholders_keep_indexing_stable():
    reg = _registry(3)
    del reg["svc1"].summaries[2]
    qs = [
        ("svc0", 0, PARTS - 1),
        ("ghost", 0, 3),  # unknown tenant
        ("svc1", 2, 2),  # only the lost partition
        ("svc2", 0, 0),
    ]
    res = reg.query_many(qs, BETA, strict=False)
    assert float(np.asarray(res[0][0].sizes).sum()) == PARTS * N_PER
    assert res[1] == (None, float("inf"))
    assert res[2] == (None, float("inf"))
    assert float(np.asarray(res[3][0].sizes).sum()) == N_PER
    with pytest.raises(KeyError):
        reg.query_many(qs, BETA, strict=True)
    with pytest.raises(KeyError):
        reg.query_many([("svc1", 0, PARTS - 1)], BETA)  # lost partition


def test_query_many_geometric_tnode_mixed_node_resolutions():
    """Geometric trees hold different T per level — the cross-tenant pack
    pads to T_pad and must stay bit-exact."""
    reg = TenantRegistry(num_buckets=T, T_node="geometric")
    for t in range(3):
        reg.ingest_many(f"m{t}", _parts(seed=10 + t, n_parts=8))
    qs = [(f"m{t}", 0, 7) for t in range(3)] + [("m1", 2, 5)]
    res = reg.query_many(qs, BETA)
    for (name, lo, hi), (h, e) in zip(qs, res):
        h2, e2 = reg[name].query(lo, hi, BETA)
        np.testing.assert_array_equal(
            np.asarray(h.sizes), np.asarray(h2.sizes)
        )
        assert e == e2


# ---------------------------------------------------- shared async ingest
def test_async_pool_fans_in_many_tenants():
    reg = TenantRegistry(num_buckets=T, workers=3)
    want = {}
    for t in range(8):
        parts = _parts(seed=20 + t, n_parts=4)
        want[f"w{t}"] = parts
        for d, v in parts.items():
            reg.ingest_async(f"w{t}", d, v)
    reg.flush()
    for name, parts in want.items():
        h, _ = reg.query(name, 0, 3, BETA)
        assert float(np.asarray(h.sizes).sum()) == 4 * N_PER
        # bit-identical to a synchronous store fed the same partitions
        sync = HistogramStore(num_buckets=T)
        sync.ingest_many(parts)
        h2, e2 = sync.query(0, 3, BETA)
        np.testing.assert_array_equal(
            np.asarray(h.sizes), np.asarray(h2.sizes)
        )
    reg.close()


def test_async_pool_validates_synchronously_and_isolates_poison():
    reg = TenantRegistry(num_buckets=T)
    with pytest.raises(ValueError):
        reg.ingest_async("a", 0, np.asarray([], np.float32))
    # poison one tenant's partition; its co-batched neighbours survive
    parts = _parts(seed=5, n_parts=4)
    store = reg.tenant("a")
    orig = store._summarize_batch

    def failing(batch):
        if 2 in batch:
            raise RuntimeError("boom at pid 2")
        return orig(batch)

    store._summarize_batch = failing
    for d, v in parts.items():
        reg.ingest_async("a", d, v)
    for d, v in _parts(seed=6, n_parts=4).items():
        reg.ingest_async("b", d, v)
    with pytest.raises(RuntimeError) as ei:
        reg.flush()
    assert "tenant 'a' partition 2" in str(ei.value)
    assert sorted(store.ids()) == [0, 1, 3]
    assert sorted(reg["b"].ids()) == [0, 1, 2, 3]  # other tenant untouched
    store._summarize_batch = orig
    reg.ingest_async("a", 2, parts[2])
    reg.flush()  # error list was cleared by the raising flush
    assert sorted(store.ids()) == [0, 1, 2, 3]
    reg.close()


def test_async_pool_error_appends_hold_the_flush_lock():
    """Same invariant as the store-level race fix: pool workers append
    errors only under the registry's condition variable."""
    reg = TenantRegistry(num_buckets=T)
    reg._cv = threading.Condition(threading.Lock())  # non-reentrant
    unlocked = []

    class Guarded(list):
        def append(self, item):
            if reg._cv._lock.acquire(blocking=False):
                reg._cv._lock.release()
                unlocked.append(item)
            super().append(item)

    reg._errors = Guarded()
    store = reg.tenant("a")
    store._summarize_batch = lambda parts: (_ for _ in ()).throw(
        RuntimeError("boom")
    )
    rng = np.random.default_rng(0)
    for d in range(3):
        reg.ingest_async("a", d, rng.normal(size=16).astype(np.float32))
    with pytest.raises(RuntimeError):
        reg.flush()
    assert unlocked == []
    reg.close()


def test_poison_narrows_retry_to_the_failing_tenants_group():
    """A poison partition must not make the pool re-apply tenants whose
    groups already applied (redundant dispatches + version churn that
    kills their warm LRUs): the apply callback raises PartialBatchFailure
    carrying only the failing group's items."""
    from repro.core.workers import PartialBatchFailure

    reg = TenantRegistry(num_buckets=T)
    a, b = reg.tenant("a"), reg.tenant("b")
    a._summarize_batch = lambda parts: (_ for _ in ()).throw(
        RuntimeError("boom")
    )
    rng = np.random.default_rng(0)
    batch = [
        ("a", 0, rng.normal(size=64).astype(np.float32)),
        ("b", 0, rng.normal(size=64).astype(np.float32)),
        ("b", 1, rng.normal(size=64).astype(np.float32)),
    ]
    applies = []
    orig_b_apply = b._apply

    def counting(summs):
        applies.append(sorted(summs))
        return orig_b_apply(summs)

    b._apply = counting
    with pytest.raises(PartialBatchFailure) as ei:
        reg._apply_worker_batch(batch)
    assert [(t, pid) for t, pid, _ in ei.value.items] == [("a", 0)]
    assert applies == [[0, 1]]  # b's group applied exactly once, in bulk
    # single-group batches propagate the REAL error so the pool's
    # per-item retry records the underlying exception, not a wrapper
    with pytest.raises(RuntimeError, match="boom"):
        reg._apply_worker_batch([batch[0]])


def test_close_drains_and_pool_restarts():
    reg = TenantRegistry(num_buckets=T, workers=2)
    parts = _parts(seed=7, n_parts=4)
    for d, v in parts.items():
        reg.ingest_async("a", d, v)
    reg.close()  # must drain everything enqueued before the sentinel
    assert sorted(reg["a"].ids()) == [0, 1, 2, 3]
    reg.ingest_async("b", 0, parts[0])  # restarts the pool transparently
    reg.flush()
    assert reg["b"].ids() == [0]
    reg.close()


# ------------------------------------------------------------ persistence
def test_registry_roundtrip_one_npz(tmp_path):
    reg = _registry(4, T_node="geometric")
    path = str(tmp_path / "registry.npz")
    for _ in range(2):  # repeated saves must not accumulate tempfiles
        reg.save(path)
    import os

    assert sorted(os.listdir(tmp_path)) == ["registry.npz"]
    loaded = TenantRegistry.load(path)
    assert loaded.names() == reg.names()
    assert loaded.num_buckets == T and loaded.T_node == "geometric"
    # tree nodes restored — answers (and eps) identical, no re-merge
    for name in reg.names():
        assert (
            loaded[name]._tree.nodes.keys() == reg[name]._tree.nodes.keys()
        )
    qs = [(n, 1, 4) for n in reg.names()]
    for (h1, e1), (h2, e2) in zip(
        reg.query_many(qs, BETA), loaded.query_many(qs, BETA)
    ):
        np.testing.assert_array_equal(
            np.asarray(h1.boundaries), np.asarray(h2.boundaries)
        )
        np.testing.assert_array_equal(
            np.asarray(h1.sizes), np.asarray(h2.sizes)
        )
        assert e1 == e2


def test_registry_load_rejects_store_files(tmp_path):
    store = HistogramStore(num_buckets=T)
    store.ingest_many(_parts(seed=1, n_parts=3))
    path = str(tmp_path / "store.npz")
    store.save(path)
    with pytest.raises(ValueError):
        TenantRegistry.load(path)


# ------------------------------------------------------------- telemetry
def test_telemetry_hub_tracks_many_metrics():
    hub = TelemetryHub(T=64)
    rng = np.random.default_rng(0)
    truth = {}
    for metric in ("step_time", "grad_norm", "latency"):
        vals = []
        for step in range(4):
            v = np.abs(rng.normal(size=300)).astype(np.float32)
            hub.record(metric, step, v)
            vals.append(v)
        truth[metric] = np.concatenate(vals)
    assert hub.metrics() == ["grad_norm", "latency", "step_time"]
    for metric, pooled in truth.items():
        got = float(hub.quantile(metric, 0, 3, 0.95))
        true = float(np.quantile(pooled, 0.95))
        # rank-error guarantee translated loosely to a value check
        assert abs(got - true) <= np.ptp(pooled) * 0.1
    panels = [(m, 0, 3) for m in hub.metrics()] + [("missing", 0, 3)]
    hub.registry.merge_dispatches = 0
    res = hub.dashboard(panels, beta=BETA)
    assert hub.registry.merge_dispatches <= 1
    assert res[-1] == (None, float("inf"))
    for h, _ in res[:-1]:
        assert float(np.asarray(h.sizes).sum()) == 4 * 300
    hub.close()


def test_telemetry_hub_async_record():
    hub = TelemetryHub(T=T, async_record=True)
    rng = np.random.default_rng(1)
    for step in range(3):
        hub.record("loss", step, np.abs(rng.normal(size=200)).astype(np.float32))
    hub.flush()
    h, _ = hub.registry.query("loss", 0, 2, BETA)
    assert float(np.asarray(h.sizes).sum()) == 3 * 200
    hub.close()
