"""Every declared failpoint is injectable — exercising the sites that
had no test references before the failpoint lint rule existed
(``scripts/analyze.py`` now fails CI for any ``faults.SITES`` member no
test touches: arena.*, snapshot.save/load, checkpoint.*, repl.*).

Each test arms the site, drives the real call path through it, and
checks both the fault delivery and that disarming restores service —
the minimum bar for "this failpoint would actually help debug an
outage".
"""
import os

import numpy as np
import pytest

from repro.core import faults
from repro.core.arena import NodeArena
from repro.core.replication import DirTransport, Follower, Replicator
from repro.core.stream import HistogramStore
from repro.core.tenant import TenantRegistry
from repro.serve.subscriptions import SubscriptionPlane


def _store(tmp_path, n=3):
    store = HistogramStore(num_buckets=8)
    rng = np.random.default_rng(0)
    for pid in range(n):
        store.ingest(pid, rng.normal(size=128))
    return store


def _arena_with_row():
    arena = NodeArena()
    b = np.linspace(0.0, 1.0, 9, dtype=np.float32)
    s = np.ones(8, dtype=np.float32)
    row = arena.alloc(8, b, s)
    return arena, row, b, s


def test_arena_alloc_faultable():
    arena, _row, b, s = _arena_with_row()
    with faults.inject("arena.alloc"):
        with pytest.raises(faults.FaultError):
            arena.alloc(8, b, s)
        # the block path hits the same site
        with pytest.raises(faults.FaultError):
            arena.alloc_block(8, b[None, :], s[None, :])
    assert isinstance(arena.alloc(8, b, s), int)  # healed on disarm


def test_arena_rows_faultable():
    arena, row, b, s = _arena_with_row()
    with faults.inject("arena.rows"):
        with pytest.raises(faults.FaultError):
            arena.rows(8, [row])
    rb, rs = arena.rows(8, [row])
    np.testing.assert_array_equal(rb[0], b)
    np.testing.assert_array_equal(rs[0], s)


def test_arena_gather_faultable():
    arena, row, b, _s = _arena_with_row()
    with faults.inject("arena.gather"):
        with pytest.raises(faults.FaultError):
            arena.device(8)
    db, _ds = arena.device(8)
    np.testing.assert_allclose(np.asarray(db)[row], b)


def test_snapshot_save_faultable(tmp_path):
    store = _store(tmp_path)
    snap = str(tmp_path / "snap.npz")
    with faults.inject("snapshot.save"):
        with pytest.raises(faults.FaultError):
            store.save(snap)
    assert not os.path.exists(snap)  # the failed save published nothing
    store.save(snap)
    assert os.path.exists(snap)


def test_snapshot_load_faultable(tmp_path):
    store = _store(tmp_path)
    snap = str(tmp_path / "snap.npz")
    store.save(snap)
    with faults.inject("snapshot.load"):
        with pytest.raises(faults.FaultError):
            HistogramStore.load(snap)
    loaded = HistogramStore.load(snap)
    assert len(loaded.summaries) == len(store.summaries)


def _plane_with_sub():
    reg = TenantRegistry(num_buckets=8)
    plane = SubscriptionPlane(reg)
    sub = plane.subscribe("m", 0, 8, 16)
    rng = np.random.default_rng(0)
    reg.ingest("m", 0, rng.normal(size=64))
    plane.flush()
    [first] = sub.drain()
    assert not first.degraded  # primed: last-known-good is recorded
    return reg, plane, sub


def test_subs_eval_faultable():
    """An armed ``subs.eval`` turns the evaluation pass degraded (the
    last-known-good contract); disarming heals to a fresh push."""
    reg, plane, sub = _plane_with_sub()
    try:
        rng = np.random.default_rng(1)
        with faults.inject("subs.eval"):
            reg.ingest("m", 1, rng.normal(size=64))
            plane.flush()
            ups = sub.drain()
            assert ups and all(u.degraded for u in ups)
            assert plane.eval_failures >= 1
        plane.flush()  # healed: the still-stale window re-evaluates fresh
        ups = sub.drain()
        assert ups and not ups[-1].degraded
        assert ups[-1].version == reg["m"].version
    finally:
        plane.close()
        reg.close()


def test_subs_deliver_faultable():
    """An armed ``subs.deliver`` loses no answers: the subscriber stays
    at its old version and the next pass after disarm re-delivers from
    the plane's answer cache — without a fresh merge dispatch."""
    reg, plane, sub = _plane_with_sub()
    try:
        rng = np.random.default_rng(2)
        with faults.inject("subs.deliver"):
            reg.ingest("m", 1, rng.normal(size=64))
            plane.flush()
            assert sub.drain() == []  # delivery faulted, nothing enqueued
            assert plane.deliver_failures >= 1
        batches = plane.stats()["eval_batches"]
        plane.flush()  # redelivery comes from the cache: no new dispatch
        assert plane.stats()["eval_batches"] == batches
        ups = sub.drain()
        assert ups and not ups[-1].degraded
        assert ups[-1].version == reg["m"].version
    finally:
        plane.close()
        reg.close()


def _repl_pair(tmp_path):
    reg = TenantRegistry(num_buckets=8, wal_dir=str(tmp_path / "pwal"))
    standby = str(tmp_path / "standby")
    repl = Replicator(reg._wal, [DirTransport(standby)]).attach(reg)
    return reg, repl, standby


def test_repl_ship_faultable(tmp_path):
    """An armed ``repl.ship`` fails the ingest *ack* (ship-before-ack —
    the caller must not believe the record replicated); disarming lets
    the re-ship converge the follower from the tracked offsets."""
    reg, repl, standby = _repl_pair(tmp_path)
    rng = np.random.default_rng(0)
    with faults.inject("repl.ship"):
        with pytest.raises(faults.FaultError):
            reg.ingest("m", 0, rng.normal(size=64).astype(np.float32))
        assert repl.stats()["ship_failures"] == 0  # faulted pre-lock
    # healed: the next ingest ships its record AND the stranded one
    reg.ingest("m", 1, rng.normal(size=64).astype(np.float32))
    f = Follower(standby, num_buckets=8)
    assert f.tail() == 2
    f.close()
    reg.close()


def test_repl_tail_faultable(tmp_path):
    reg, _repl, standby = _repl_pair(tmp_path)
    rng = np.random.default_rng(1)
    reg.ingest("m", 0, rng.normal(size=64).astype(np.float32))
    f = Follower(standby, num_buckets=8)
    with faults.inject("repl.tail"):
        with pytest.raises(faults.FaultError):
            f.tail()
    assert f.stats()["records_applied"] == 0  # nothing half-applied
    assert f.tail() == 1  # healed on disarm
    f.close()
    reg.close()


def test_repl_apply_faultable_idempotent_rescan(tmp_path):
    """A fault mid-apply commits NO scan state: the next tail re-scans
    the same bytes and the pid dedup keeps the replay exactly-once."""
    reg, _repl, standby = _repl_pair(tmp_path)
    rng = np.random.default_rng(2)
    for pid in range(3):
        reg.ingest("m", pid, rng.normal(size=64).astype(np.float32))
    f = Follower(standby, num_buckets=8)
    with faults.inject("repl.apply"):
        with pytest.raises(faults.FaultError):
            f.tail()
    st = f.stats()
    assert st["apply_failures"] == 1 and st["applied_lsn"] == 0
    assert f.tail() == 3  # full re-scan, every record exactly once
    assert f.lag()["records"] == 0
    f.close()
    reg.close()


def test_repl_promote_faultable(tmp_path):
    reg, repl, standby = _repl_pair(tmp_path)
    rng = np.random.default_rng(3)
    reg.ingest("m", 0, rng.normal(size=64).astype(np.float32))
    f = Follower(standby, num_buckets=8)
    f.tail()
    with faults.inject("repl.promote"):
        with pytest.raises(faults.FaultError):
            f.promote(fence=repl.fence)
    assert f.promoted_epoch is None  # faulted before any state change
    reg.ingest("m", 1, rng.normal(size=64).astype(np.float32))  # not fenced
    promoted = f.promote(fence=repl.fence)  # healed on disarm
    assert f.promoted_epoch == 1
    assert promoted["m"].version > 0
    f.close()
    reg.close()


def test_checkpoint_save_and_restore_faultable(tmp_path):
    from repro.checkpoint.checkpoint import (
        restore_checkpoint,
        save_checkpoint,
    )

    params = {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}
    ckpt = str(tmp_path / "ckpt")
    with faults.inject("checkpoint.save"):
        with pytest.raises(faults.FaultError):
            save_checkpoint(ckpt, 1, params)
    save_checkpoint(ckpt, 1, params)
    with faults.inject("checkpoint.restore"):
        with pytest.raises(faults.FaultError):
            restore_checkpoint(ckpt, None, params)
    got, _opt, step = restore_checkpoint(ckpt, None, params)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(got["w"]), params["w"])
