"""Amortized collapse mode (``HistogramStore(collapse="amortized")``).

The canonical collapse contract (post-eviction tree bit-identical to a
fresh build over the survivors) forces O(window) merge *work* per window
slide: a shift by one re-pairs every level.  The amortized mode defers the
re-root until the dead slot prefix exceeds half the capacity, so a
high-frequency sliding window pays O(log W) merge work per ingest
amortized.  The relaxation is explicit: answers are no longer bit-equal to
a fresh rebuild, but every answer remains an exact merge of its selected
nodes whose reported ``eps_total`` dominates the measured error — which is
what these tests machine-check, alongside the merge-work saving itself.
"""
import numpy as np
import pytest

from repro.core import HistogramStore, SlidingWindow, TenantRegistry
from repro.core import interval_tree as it_mod

T = 32
W = 16
BETA = 16


def _stream(mode, days, t_node=None, rng_seed=7):
    rng = np.random.default_rng(rng_seed)
    parts = {d: rng.normal(size=256).astype(np.float32) for d in range(days)}
    store = HistogramStore(
        num_buckets=T,
        T_node=t_node,
        retention=SlidingWindow(W),
        collapse=mode,
    )
    it_mod.reset_pullup_stats()
    for d in range(days):
        store.ingest(d, parts[d])
    stats = it_mod.reset_pullup_stats()
    return store, parts, stats


def test_amortized_does_asymptotically_less_merge_work():
    """The ROADMAP claim, machine-checked: per-slide merge work drops from
    O(W) to O(log W) amortized — at W=16 over 100+ slides that is a >2×
    reduction in merged pairs (and it widens with W)."""
    _, _, canonical = _stream("canonical", 120)
    _, _, amortized = _stream("amortized", 120)
    assert amortized["pair_merges"] * 2 < canonical["pair_merges"]


@pytest.mark.parametrize("t_node", [None, "geometric"])
def test_amortized_answers_stay_within_eps_total(t_node):
    store, parts, _ = _stream("amortized", 90, t_node=t_node)
    lo, hi = store.ids()[0], store.ids()[-1]
    assert hi - lo + 1 == W  # window enforced
    for a, b in [(lo, hi), (lo + 3, hi - 2), (hi, hi)]:
        h, eps = store.query(a, b, BETA)
        pooled = np.sort(np.concatenate([parts[d] for d in range(a, b + 1)]))
        n = pooled.size
        sizes = np.asarray(h.sizes, np.float64)
        assert float(sizes.sum()) == pytest.approx(n, abs=0.5)
        assert np.abs(sizes - n / BETA).max() <= eps + 1e-3
        bnd = np.asarray(h.boundaries, np.float64)
        true = (
            np.searchsorted(pooled, bnd[1:], side="left")
            - np.searchsorted(pooled, bnd[:-1], side="left")
        ).astype(np.float64)
        true[-1] += np.sum(pooled == bnd[-1])
        assert np.abs(true - n / BETA).max() <= eps + 1e-3


def test_dead_prefix_stays_below_half_capacity():
    """The slack invariant: the un-collapsed dead prefix never exceeds half
    the capacity, so depth (and geometric resolution) stays bounded at
    one extra level over the fresh-build depth."""
    store, _, _ = _stream("amortized", 200)
    tree = store._tree
    lo = min(s for (lvl, s) in tree.nodes if lvl == 0)
    assert lo < tree.capacity // 2
    assert tree.capacity <= 4 * W  # bounded: ≤ fresh depth + 1 level


def test_collapse_mode_persists_and_rejects_unknown(tmp_path):
    store, _, _ = _stream("amortized", 40)
    path = str(tmp_path / "amortized.npz")
    store.save(path)
    loaded = HistogramStore.load(path)
    assert loaded.collapse == "amortized"
    assert loaded._tree.collapse_mode == "amortized"
    h0, e0 = store.query(*store.ids()[0:1] * 2, BETA)
    h1, e1 = loaded.query(*loaded.ids()[0:1] * 2, BETA)
    np.testing.assert_array_equal(np.asarray(h0.sizes), np.asarray(h1.sizes))
    assert e0 == e1
    with pytest.raises(ValueError):
        HistogramStore(num_buckets=T, collapse="sometimes")
    with pytest.raises(ValueError):
        it_mod.IntervalTree(T, collapse="sometimes")


def test_registry_shares_collapse_mode_and_persists_it(tmp_path):
    rng = np.random.default_rng(8)
    reg = TenantRegistry(
        num_buckets=T,
        shared_arena=True,
        retention=SlidingWindow(4),
        collapse="amortized",
    )
    for ti in range(3):
        for d in range(12):
            reg.ingest(f"svc{ti}", d, rng.normal(size=128).astype(np.float32))
    assert all(reg[n]._tree.collapse_mode == "amortized" for n in reg.names())
    path = str(tmp_path / "reg.npz")
    reg.save(path)
    loaded = TenantRegistry.load(path)
    assert loaded.collapse == "amortized"
    assert all(
        loaded[n]._tree.collapse_mode == "amortized" for n in loaded.names()
    )
    qs = [(n, 8, 11) for n in reg.names()]
    for (h0, e0), (h1, e1) in zip(
        reg.query_many(qs, BETA), loaded.query_many(qs, BETA)
    ):
        np.testing.assert_array_equal(
            np.asarray(h0.sizes), np.asarray(h1.sizes)
        )
        assert e0 == e1
    reg.close()
    loaded.close()
