"""Failpoint framework + self-healing integration suite.

Covers the chaos plane end to end, deterministically (no sleeps — every
interleaving is event-sequenced, every trigger schedule seeded):

* the failpoint registry itself (arming, triggers, scoping, counters);
* the WAL all-or-nothing append regression (a failed write must roll the
  partial record back out of the segment — stray bytes there silently
  drop every later record at recovery);
* transient-fault healing (fsync retry) and backpressure when the disk
  stays sick;
* the close-vs-retry interleaving of the ingest pool (bounded close that
  never drops the retried item);
* per-tenant circuit breakers (quarantine lifecycle) and degraded
  serving with honestly widened eps;
* the integrity scrubber and salvage recovery from a corrupted snapshot;
* resource hygiene: fds and threads flat across repeated
  crash/recover/quarantine cycles.
"""
import dataclasses
import gc
import os
import threading

import numpy as np
import pytest

from repro.core import (
    BreakerPolicy,
    HistogramStore,
    IngestBackpressure,
    IngestPool,
    RetryPolicy,
    TenantQuarantined,
    TenantRegistry,
    WriteAheadLog,
    faults,
    scrub_store,
    verify_snapshot,
)
from repro.serve import HistogramService

T = 8
BETA = 16


@pytest.fixture(autouse=True)
def _disarm():
    faults.reset()
    yield
    faults.reset()


def _vals(rng, n=32):
    return rng.normal(size=n).astype(np.float32)


def _assert_same_answer(a, b):
    (ha, ea), (hb, eb) = a, b
    assert np.array_equal(np.asarray(ha.boundaries), np.asarray(hb.boundaries))
    assert np.array_equal(np.asarray(ha.sizes), np.asarray(hb.sizes))
    assert ea == eb


# --------------------------------------------------------------------------
# the framework itself
# --------------------------------------------------------------------------


def test_disarmed_hit_returns_default():
    assert faults.hit("nowhere") is None
    assert faults.hit("nowhere", default=42, ctx=1) == 42
    assert not faults.is_armed("nowhere")


def test_inject_raises_and_scopes():
    with faults.inject("x", exc=OSError(28, "No space left on device")):
        assert faults.is_armed("x")
        with pytest.raises(OSError):
            faults.hit("x")
    assert not faults.is_armed("x")
    assert faults.hit("x") is None  # disarmed again


def test_default_effect_is_fault_error():
    with faults.inject("x"):
        with pytest.raises(faults.FaultError):
            faults.hit("x")


def test_times_budget_and_after_skip():
    with faults.inject("x", times=2, after=1) as fp:
        assert faults.hit("x") is None  # skipped (after=1)
        with pytest.raises(faults.FaultError):
            faults.hit("x")
        with pytest.raises(faults.FaultError):
            faults.hit("x")
        assert faults.hit("x") is None  # budget spent
        assert fp.hits == 4 and fp.fires == 2


def test_prob_schedule_is_seed_deterministic():
    def schedule(seed):
        fired = []
        with faults.inject("x", prob=0.5, seed=seed):
            for i in range(32):
                try:
                    faults.hit("x")
                    fired.append(False)
                except faults.FaultError:
                    fired.append(True)
        return fired

    assert schedule(7) == schedule(7)
    assert schedule(7) != schedule(8)
    assert any(schedule(7)) and not all(schedule(7))


def test_match_filters_on_context():
    with faults.inject(
        "x", match=lambda ctx: ctx.get("tenant") == "bad"
    ) as fp:
        assert faults.hit("x", tenant="good") is None
        with pytest.raises(faults.FaultError):
            faults.hit("x", tenant="bad")
        assert fp.hits == 1  # match-rejected hits don't count


def test_action_return_value_reaches_site():
    with faults.inject("x", action=lambda **ctx: ctx["size"] // 2):
        assert faults.hit("x", size=10) == 5
    with faults.inject("x", action=lambda: "zero-arg"):
        assert faults.hit("x", size=10) == "zero-arg"


def test_rearming_same_name_restores_previous_on_exit():
    with faults.inject("x", exc=OSError("outer")):
        with faults.inject("x", exc=ValueError("inner")):
            with pytest.raises(ValueError):
                faults.hit("x")
        with pytest.raises(OSError):
            faults.hit("x")
    assert not faults.is_armed("x")


def test_stats_snapshot():
    with faults.inject("a", times=1), faults.inject("b", after=99):
        with pytest.raises(faults.FaultError):
            faults.hit("a")
        faults.hit("b")
        assert faults.stats() == {
            "a": {"hits": 1, "fires": 1},
            "b": {"hits": 1, "fires": 0},
        }
        assert faults.fires("a") == 1


# --------------------------------------------------------------------------
# WAL: all-or-nothing append (regression) + fsync healing
# --------------------------------------------------------------------------


def test_wal_append_failure_rolls_back_partial_record(tmp_path):
    """Regression: an append that fails mid-write used to leave a partial
    record in the segment — recovery's torn-tail scan then silently
    dropped every record appended after it."""
    rng = np.random.default_rng(0)
    wal_dir = str(tmp_path / "wal")
    wal = WriteAheadLog(wal_dir)
    wal.log(None, 0, _vals(rng))
    # injected torn write: 9 bytes of the record land, then the fault
    with faults.inject("wal.append.torn", action=lambda **ctx: 9, times=1):
        with pytest.raises(OSError):
            wal.append(None, 1, _vals(rng))
    # the failed append is rolled back: later appends are recoverable
    wal.log(None, 2, _vals(rng))
    assert wal.stats()["append_rollbacks"] == 1
    wal.close()

    re = WriteAheadLog(wal_dir)
    assert [(r.lsn, r.pid) for r in re.recovered_records()] == [
        (1, 0),
        (2, 2),  # the rolled-back LSN was re-issued, no gap and no loss
    ]
    assert re.torn_records_dropped == 0
    re.close()


class _BrokenSeekFd:
    """File-object proxy whose seek always fails (rollback-failure rig)."""

    def __init__(self, fd):
        self._fd = fd

    def seek(self, *a, **k):
        raise OSError("seek failed too")

    def __getattr__(self, name):
        return getattr(self._fd, name)


def test_wal_broken_rollback_rotates_to_fresh_segment(tmp_path):
    """If even the rollback truncate fails, the fd is marked broken and
    the next append must go to a fresh segment — the stray bytes become
    a scannable torn tail instead of a mid-segment hole."""
    rng = np.random.default_rng(0)
    wal = WriteAheadLog(str(tmp_path / "wal"))
    wal.log(None, 0, _vals(rng))

    wal._fd = _BrokenSeekFd(wal._fd)
    with faults.inject("wal.append.torn", action=lambda **ctx: 7, times=1):
        with pytest.raises(OSError):
            wal.append(None, 1, _vals(rng))
    assert wal._fd_broken
    wal.log(None, 2, _vals(rng))  # rotated to a fresh segment
    assert not wal._fd_broken
    wal.close()

    re = WriteAheadLog(str(tmp_path / "wal"))
    pids = [r.pid for r in re.recovered_records()]
    assert 0 in pids and 2 in pids and 1 not in pids
    assert re.torn_records_dropped == 1  # the stray prefix, detected
    re.close()


def test_wal_fsync_transient_failure_heals_inside_commit(tmp_path):
    rng = np.random.default_rng(0)
    wal = WriteAheadLog(
        str(tmp_path / "wal"),
        retry=RetryPolicy(attempts=3, base=0.0, jitter=0.0),
    )
    with faults.inject("wal.fsync", exc=OSError(5, "EIO"), times=2):
        wal.log(None, 0, _vals(rng))  # two failures, third attempt lands
    st = wal.stats()
    assert st["fsync_retries"] == 2
    assert st["synced_lsn"] == 1
    wal.close()

    re = WriteAheadLog(str(tmp_path / "wal"))
    assert [r.pid for r in re.recovered_records()] == [0]
    re.close()


def test_wal_fsync_persistent_failure_propagates(tmp_path):
    rng = np.random.default_rng(0)
    wal = WriteAheadLog(
        str(tmp_path / "wal"),
        retry=RetryPolicy(attempts=2, base=0.0, jitter=0.0),
    )
    with faults.inject("wal.fsync", exc=OSError(28, "ENOSPC")):
        with pytest.raises(OSError):
            wal.log(None, 0, _vals(rng))
    wal.close()


# --------------------------------------------------------------------------
# ingest pool: backpressure + the close-vs-retry interleaving
# --------------------------------------------------------------------------


def _make_pool(tmp_path, applied, retry=None, wal=None):
    return IngestPool(
        apply_batch=lambda items: applied.extend(items),
        wrap_error=lambda item, exc: (item, exc),
        queue_size=64,
        name="test-pool",
        retry=retry or RetryPolicy(attempts=3, base=0.0, jitter=0.0),
        wal=wal,
        wal_record=(None if wal is None else (lambda it: (None, it[0], it[1]))),
    )


def test_submit_backpressure_when_wal_append_fails(tmp_path):
    rng = np.random.default_rng(0)
    wal = WriteAheadLog(
        str(tmp_path / "wal"),
        retry=RetryPolicy(attempts=2, base=0.0, jitter=0.0),
    )
    applied = []
    pool = _make_pool(
        tmp_path,
        applied,
        retry=RetryPolicy(attempts=2, base=0.0, jitter=0.0),
        wal=wal,
    )
    with faults.inject("wal.append", exc=OSError(28, "ENOSPC")):
        with pytest.raises(IngestBackpressure):
            pool.submit((0, _vals(rng)))
    # NOTHING was enqueued: the caller still owns the partition
    assert pool.stats()["pending"] == 0
    assert pool.stats()["backpressure_rejects"] == 1
    assert pool.stats()["wal_append_retries"] == 1
    # the disk healed: the resubmit is accepted and applied
    pool.submit((0, _vals(rng)))
    assert pool.drain() == []
    assert [pid for pid, _v in applied] == [0]
    pool.close()
    wal.close()


def test_submit_backpressure_when_fsync_fails_item_still_applies(tmp_path):
    rng = np.random.default_rng(0)
    wal = WriteAheadLog(
        str(tmp_path / "wal"),
        retry=RetryPolicy(attempts=1, base=0.0, jitter=0.0),
    )
    applied = []
    pool = _make_pool(tmp_path, applied, wal=wal)
    with faults.inject("wal.fsync", exc=OSError(5, "EIO")):
        with pytest.raises(IngestBackpressure, match="NOT durable"):
            pool.submit((0, _vals(rng)))
    # the item entered the queue before the fsync: applied in-memory,
    # but the caller was told durability failed
    assert pool.drain() == []
    assert [pid for pid, _v in applied] == [0]
    pool.close()
    wal.close()


def test_pool_batch_crash_failpoint_isolated_by_retry():
    """A worker 'crash' mid-batch (pool.batch failpoint) makes the whole
    batch suspect; the per-item retry then applies it cleanly."""
    applied = []
    pool = IngestPool(
        apply_batch=lambda items: applied.extend(items),
        wrap_error=lambda item, exc: (item, exc),
        name="crash",
        retry=RetryPolicy(attempts=2, base=0.0, jitter=0.0),
    )
    with faults.inject("pool.batch", times=1):
        pool.submit("a")
        assert pool.drain() == []
    assert applied == ["a"]
    pool.close()


def test_close_interrupts_retry_backoff_without_dropping_item():
    """Deterministic close-vs-retry interleaving (no sleeps).

    The retry backoff is ~1000 s: if close() failed to interrupt the
    wait, this test would hang; if interrupting skipped the remaining
    attempts, the item would be dropped.  Sequence: batch apply fails →
    per-item retry attempt 1 fails → worker parks in the backoff wait
    (the pool.retry failpoint signals us) → we close() → the wait
    returns immediately → the remaining attempt succeeds.
    """
    applied = []
    parked = threading.Event()
    calls = {"n": 0}

    def flaky(items):
        calls["n"] += 1
        if calls["n"] < 3:  # batch apply + retry attempt 1 fail
            raise OSError("injected worker crash")
        applied.extend(items)

    pool = IngestPool(
        apply_batch=flaky,
        wrap_error=lambda item, exc: (item, exc),
        name="close-race",
        retry=RetryPolicy(attempts=2, base=1000.0, cap=1000.0, jitter=0.0),
    )
    with faults.inject("pool.retry", action=lambda **ctx: parked.set()):
        pool.submit("item-a")
        assert parked.wait(timeout=30.0), "worker never reached the backoff"
        pool.close()  # must interrupt the 1000 s wait and join promptly
    assert applied == ["item-a"]  # the remaining attempt ran and healed
    assert pool.stats()["pending"] == 0
    assert pool.stats()["apply_retries"] == 1
    assert pool.errors == []


def test_close_interrupts_retry_of_permanently_poisoned_item():
    """Same interleaving, but the item never heals: close() still returns
    promptly and the failure is recorded (not silently dropped)."""
    parked = threading.Event()

    def poison(items):
        raise ValueError("poison")

    pool = IngestPool(
        apply_batch=poison,
        wrap_error=lambda item, exc: (item, exc),
        name="close-race-poison",
        retry=RetryPolicy(attempts=3, base=1000.0, cap=1000.0, jitter=0.0),
    )
    with faults.inject("pool.retry", action=lambda **ctx: parked.set()):
        pool.submit("bad")
        assert parked.wait(timeout=30.0)
        pool.close()
    errs = pool.drain()
    assert [item for item, _e in errs] == ["bad"]
    assert isinstance(errs[0][1], ValueError)


# --------------------------------------------------------------------------
# circuit breaker: quarantine lifecycle through the registry
# --------------------------------------------------------------------------


class FakeClock:
    def __init__(self):
        self.now = 0.0

    def __call__(self):
        return self.now


def _breaker_registry(threshold=2, cooldown=10.0):
    clock = FakeClock()
    reg = TenantRegistry(
        num_buckets=T,
        breaker=BreakerPolicy(
            threshold=threshold, cooldown=cooldown, probes=1, clock=clock
        ),
    )
    return reg, clock


def test_breaker_quarantines_failing_tenant_and_probes_back():
    rng = np.random.default_rng(0)
    reg, clock = _breaker_registry(threshold=2, cooldown=10.0)
    reg.ingest("ok", 0, _vals(rng))

    bad_only = {"match": lambda ctx: ctx.get("tenant") == "bad"}
    with faults.inject("tenant.apply", **bad_only):
        for _ in range(2):
            with pytest.raises(faults.FaultError):
                reg.ingest("bad", 0, _vals(rng))
        # tripped: rejected at the door, the fault site is never reached
        with pytest.raises(TenantQuarantined):
            reg.ingest("bad", 1, _vals(rng))
        with pytest.raises(TenantQuarantined):
            reg.ingest_async("bad", 1, _vals(rng))
    # healthy tenants are unaffected by the quarantine
    reg.ingest("ok", 1, _vals(rng))
    health = reg.health()
    assert health["status"] == "degraded"
    assert health["quarantined"] == ["bad"]
    assert health["breakers"]["bad"]["trips"] == 1

    clock.now = 9.0
    with pytest.raises(TenantQuarantined):
        reg.ingest("bad", 1, _vals(rng))
    clock.now = 10.0  # cooldown over: one probe admitted, fault gone
    reg.ingest("bad", 1, _vals(rng))
    assert reg.health()["breakers"]["bad"]["state"] == "closed"
    assert reg.health()["status"] == "ok"
    assert sorted(reg["bad"].ids()) == [1]
    reg.close()


def test_breaker_probe_failure_reopens():
    rng = np.random.default_rng(0)
    reg, clock = _breaker_registry(threshold=1, cooldown=5.0)
    with faults.inject(
        "tenant.apply", match=lambda ctx: ctx.get("tenant") == "bad"
    ):
        with pytest.raises(faults.FaultError):
            reg.ingest("bad", 0, _vals(rng))
        clock.now = 5.0
        with pytest.raises(faults.FaultError):  # probe admitted, fails
            reg.ingest("bad", 0, _vals(rng))
        with pytest.raises(TenantQuarantined):  # re-opened
            reg.ingest("bad", 0, _vals(rng))
    assert reg.health()["breakers"]["bad"]["trips"] == 2
    reg.close()


def test_async_terminal_failure_counts_against_breaker():
    rng = np.random.default_rng(0)
    reg, _clock = _breaker_registry(threshold=1)
    reg._pool.retry = RetryPolicy(attempts=2, base=0.0, jitter=0.0)
    with faults.inject(
        "tenant.apply", match=lambda ctx: ctx.get("tenant") == "bad"
    ):
        reg.ingest_async("bad", 0, _vals(rng))
        with pytest.raises(RuntimeError):
            reg.flush()  # the poison surfaced...
    assert reg.health()["quarantined"] == ["bad"]  # ...and tripped the breaker
    reg.close()


# --------------------------------------------------------------------------
# degraded serving: last known-good + honestly widened eps
# --------------------------------------------------------------------------


def _fresh_registry(rng, pids=range(4)):
    reg = TenantRegistry(num_buckets=T)
    data = {pid: _vals(rng, 64) for pid in pids}
    reg.ingest_many("m", data)
    return reg, data


def test_degraded_answer_serves_last_good_with_widened_eps():
    rng = np.random.default_rng(0)
    reg, data = _fresh_registry(rng)
    # prime the last-known-good cache for the (0, 4) panel while pid 4
    # doesn't exist yet (strict=False skips the absent window)
    [primed] = reg.query_many(
        [("m", 0, 4)], BETA, strict=False, degraded_ok=True
    )
    assert not getattr(primed, "degraded", False)

    # interval membership changes: 50 units of mass added to the panel
    reg.ingest("m", 4, _vals(rng, 50))
    with faults.inject("tenant.merge"):
        with pytest.raises(faults.FaultError):
            reg.query_many([("m", 0, 4)], BETA)  # strict callers still fail
        [ans] = reg.query_many(
            [("m", 0, 4)], BETA, strict=False, degraded_ok=True
        )
    assert ans.degraded
    h, eps = ans  # unpacks like the historical 2-tuple
    _assert_same_answer((h, eps - 50), primed)  # widened by the added mass
    assert reg.degraded_served == 1
    assert ans.stale_version is not None

    # the fault cleared: the same query is answered fresh again
    [healed] = reg.query_many(
        [("m", 0, 4)], BETA, strict=False, degraded_ok=True
    )
    assert not getattr(healed, "degraded", False)
    reg.close()


def test_degraded_widening_counts_removed_mass_too():
    rng = np.random.default_rng(1)
    reg, data = _fresh_registry(rng)
    [fresh] = reg.query_many([("m", 0, 3)], BETA, degraded_ok=True)
    removed_mass = reg["m"].summaries[0].n
    reg["m"].evict([0])
    with faults.inject("tenant.merge"):
        [ans] = reg.query_many(
            [("m", 0, 3)], BETA, strict=False, degraded_ok=True
        )
    assert ans.degraded
    assert ans[1] == fresh[1] + removed_mass
    reg.close()


def test_degraded_without_cached_answer_is_inf_placeholder():
    rng = np.random.default_rng(2)
    reg, _ = _fresh_registry(rng)
    with faults.inject("tenant.merge"):
        [ans] = reg.query_many([("m", 0, 3)], BETA, degraded_ok=True)
    assert ans.degraded and ans[0] is None and ans[1] == float("inf")
    reg.close()


def test_deadline_past_serves_degraded_without_dispatch():
    rng = np.random.default_rng(3)
    reg, _ = _fresh_registry(rng)
    [fresh] = reg.query_many([("m", 0, 3)], BETA, degraded_ok=True)
    reg["m"]._tree._invalidate()  # force a cache miss next time
    reg._clock = lambda: 100.0
    before = reg.merge_dispatches
    [ans] = reg.query_many(
        [("m", 0, 3)], BETA, degraded_ok=True, deadline=50.0
    )
    assert ans.degraded
    _assert_same_answer((ans[0], ans[1]), fresh)  # nothing changed: no widening
    assert reg.merge_dispatches == before  # the dispatch was skipped
    reg.close()


def test_service_query_many_defaults_degraded_ok(tmp_path):
    rng = np.random.default_rng(4)
    svc = HistogramService(str(tmp_path / "data"), num_buckets=T)
    svc.record("latency", 0, _vals(rng, 64))
    svc.record("latency", 1, _vals(rng, 64))
    [fresh] = svc.query_many([("latency", 0, 1)], beta=BETA)
    with faults.inject("tenant.merge"):
        svc.record("latency", 2, _vals(rng, 16))
        [ans] = svc.query_many([("latency", 0, 2)], beta=BETA)
    assert ans.degraded  # the service plane degrades instead of raising
    assert svc.health()["degraded_served"] == 1
    svc.close()


# --------------------------------------------------------------------------
# integrity scrubber + snapshot salvage
# --------------------------------------------------------------------------


def _rot_summary(store, pid):
    """Simulate in-memory bit-rot of one stored summary's sizes row."""
    s = store.summaries[pid]
    bad = np.array(s.sizes)
    bad[0] += 1.0
    store.summaries[pid] = dataclasses.replace(s, sizes=bad)


def test_scrub_detects_in_memory_corruption_and_repairs_from_wal(tmp_path):
    rng = np.random.default_rng(5)
    reg = TenantRegistry(num_buckets=T, wal_dir=str(tmp_path / "wal"))
    data = {pid: _vals(rng, 64) for pid in range(3)}
    reg.ingest_many("m", data)
    assert reg.scrub() == {
        "tenants": 1,
        "checked": 3,
        "corrupt": {},
        "repaired": {},
        "dropped": {},
    }
    _rot_summary(reg["m"], 1)  # bit-rot in the heap
    rep = scrub_store(reg["m"])
    assert rep["corrupt"] == [1]
    rep = reg.scrub(repair=True)
    assert rep["corrupt"] == {"m": [1]}
    assert rep["repaired"] == {"m": [1]}  # WAL still held the raw values
    assert rep["dropped"] == {}
    assert reg.health()["last_scrub"] is rep
    # the rebuilt tenant answers bit-identically to a never-corrupted one
    replica = TenantRegistry(num_buckets=T)
    replica.ingest_many("m", data)
    _assert_same_answer(
        reg.query_many([("m", 0, 2)], BETA)[0],
        replica.query_many([("m", 0, 2)], BETA)[0],
    )
    reg.close()
    replica.close()


def test_scrub_drops_partition_with_no_wal_record(tmp_path):
    rng = np.random.default_rng(6)
    reg = TenantRegistry(num_buckets=T, wal_dir=str(tmp_path / "wal"))
    reg.ingest_many("m", {pid: _vals(rng, 64) for pid in range(3)})
    reg.save(str(tmp_path / "reg.npz"))  # truncates covered WAL segments
    # rotate enough segments that truncation can reclaim pid 1's record
    wal_paths = list(reg._wal._segments)
    for p in wal_paths:
        if os.path.exists(p):
            os.unlink(p)  # out-of-band loss of the raw values
    reg._wal._segments.clear()
    _rot_summary(reg["m"], 1)
    rep = reg.scrub(repair=True)
    assert rep["corrupt"] == {"m": [1]}
    assert rep["dropped"] == {"m": [1]}  # unsalvageable: dropped honestly
    assert sorted(reg["m"].ids()) == [0, 2]
    # strict=False serving skips the dropped window instead of lying
    [(h, eps)] = reg.query_many([("m", 0, 2)], BETA, strict=False)
    assert h is not None
    reg.close()


def test_verify_snapshot_roundtrip_and_corruption(tmp_path):
    rng = np.random.default_rng(7)
    reg = TenantRegistry(num_buckets=T)
    reg.ingest_many("m", {pid: _vals(rng, 64) for pid in range(3)})
    path = str(tmp_path / "reg.npz")
    reg.save(path)
    rep = verify_snapshot(path)
    assert rep["ok"] and rep["checked"] > 0 and rep["bad_keys"] == []
    # flip payload bytes on disk (zip-resident bit-rot)
    with open(path, "r+b") as f:
        f.seek(os.path.getsize(path) // 2)
        f.write(b"\xff\xff\xff\xff")
    rep = verify_snapshot(path)
    assert not rep["ok"]
    reg.close()


def test_recover_salvage_rebuilds_from_wal_when_snapshot_rots(tmp_path):
    rng = np.random.default_rng(8)
    data_dir = tmp_path / "data"
    svc = HistogramService(str(data_dir), num_buckets=T)
    data = {pid: _vals(rng, 64) for pid in range(4)}
    for pid, v in data.items():
        svc.record("m", pid, v)
    svc.checkpoint()
    for pid in (4, 5):  # acked after the checkpoint: live only in the WAL
        data[pid] = _vals(rng, 64)
        svc.record("m", pid, data[pid])
    svc.close()

    # snapshot.save.corrupt models bit-rot that survives the atomic
    # rename; here the file already exists, so rot it directly
    snap = str(data_dir / "registry.npz")
    with open(snap, "r+b") as f:
        f.seek(os.path.getsize(snap) // 2)
        f.write(b"\xde\xad\xbe\xef")

    svc2 = HistogramService(str(data_dir), num_buckets=T)
    assert svc2.salvage is not None and not svc2.salvage["ok"]
    assert os.path.exists(snap + ".corrupt")  # quarantined, not deleted
    # everything the WAL still holds is rebuilt — at minimum the suffix
    # acked after the checkpoint — instead of serving rotted bytes or
    # crash-looping; the snapshot is quarantined for operators
    present = set(svc2.registry["m"].ids()) if "m" in svc2.registry else set()
    assert {4, 5} <= present
    replica = TenantRegistry(num_buckets=T)
    replica.ingest_many("m", {pid: data[pid] for pid in sorted(present)})
    lo, hi = min(present), max(present)
    _assert_same_answer(
        svc2.query_many([("m", lo, hi)], beta=BETA)[0],
        replica.query_many([("m", lo, hi)], BETA)[0],
    )
    svc2.close()
    replica.close()


def test_snapshot_save_corrupt_failpoint_is_caught_by_verify(tmp_path):
    rng = np.random.default_rng(9)
    reg = TenantRegistry(num_buckets=T)
    reg.ingest_many("m", {0: _vals(rng, 64)})
    path = str(tmp_path / "reg.npz")
    with faults.inject(
        "snapshot.save.corrupt", action=lambda **ctx: 128
    ):
        reg.save(path)  # the write "succeeds" — with rotted bytes
    assert not verify_snapshot(path)["ok"]
    reg.close()


# --------------------------------------------------------------------------
# resource hygiene: crash/recover/quarantine loops leak nothing
# --------------------------------------------------------------------------


def _fd_count():
    return len(os.listdir("/proc/self/fd"))


def test_no_fd_or_thread_leak_across_crash_recover_cycles(tmp_path):
    rng = np.random.default_rng(10)
    data = {pid: _vals(rng, 32) for pid in range(2)}
    clock = FakeClock()
    policy = BreakerPolicy(threshold=1, cooldown=1.0, clock=clock)

    def cycle(i):
        d = str(tmp_path / "data")
        reg = TenantRegistry.recover(
            os.path.join(d, "reg.npz"),
            os.path.join(d, "wal"),
            num_buckets=T,
        )
        reg.breaker_policy = policy  # runtime config, assignable post-load
        reg.ingest_many("m", data)
        reg.ingest_async("m", 2 + i, _vals(rng, 16))
        with faults.inject(
            "tenant.apply", match=lambda ctx: ctx.get("tenant") == "bad"
        ):
            with pytest.raises(faults.FaultError):
                reg.ingest("bad", 0, _vals(rng, 16))
            with pytest.raises(TenantQuarantined):
                reg.ingest("bad", 1, _vals(rng, 16))
        reg.flush()
        reg.scrub()
        if i % 2 == 0:
            reg.save(os.path.join(d, "reg.npz"))
        reg.close()
        if reg._wal is not None:
            reg._wal.close()
        # crash the rest: drop without further ceremony
        del reg

    cycle(0)  # warmup: lazy imports, jit caches, thread-pool spin-up
    gc.collect()
    fd_before = _fd_count()
    threads_before = threading.active_count()
    for i in range(1, 51):
        cycle(i)
    gc.collect()
    assert threading.active_count() <= threads_before
    assert _fd_count() <= fd_before + 2  # slack for allocator/inspector fds
