"""Retention subsystem (core/retention.py + evict_leaves + registry budget).

Covers the retention lifecycle layer end to end:

* eviction/cache interaction — an answer cached before ``evict_leaves``
  (single query, batched ``query_many``, and the cross-tenant registry
  ``query_many``) is never returned after eviction: eviction bumps the
  store version and the LRU is version-keyed;
* the watermark-driven policies (TTL / SlidingWindow / MemoryBudget /
  AnyOf), swept inline on synchronous ingest and on the shared ingest
  worker between flushes for async ingest;
* lazy subtree collapse — after eviction the tree re-roots at the lowest
  surviving leaf and is *structurally identical* to a fresh build over
  the survivors (same base, depth, node keys, and node-float footprint —
  the geometric re-coarsening claim, machine-checked);
* watermark + policy persistence through save/load (store and registry);
* the registry-wide memory budget with fair per-tenant quotas.
"""
import numpy as np
import pytest

from repro.core import (
    AnyOf,
    HistogramStore,
    MemoryBudget,
    SlidingWindow,
    TTL,
    TenantRegistry,
    policy_from_spec,
)

T = 32
BETA = 8
N_PER = 200


def _parts(days, seed=0, n_per=N_PER, start=0):
    rng = np.random.default_rng(seed)
    return {
        d: rng.gumbel(size=n_per).astype(np.float32)
        for d in range(start, start + days)
    }


def _store(days=8, seed=0, **kw):
    parts = _parts(days, seed=seed)
    store = HistogramStore(num_buckets=T, **kw)
    store.ingest_many(parts)
    return store, parts


# ------------------------------------------------------------ basic evict
def test_evict_removes_partitions_and_bumps_version():
    store, _ = _store(days=8)
    v0 = store.version
    assert store.evict([0, 1, 99]) == [0, 1]  # absent ids ignored
    assert store.version > v0
    assert store.ids() == list(range(2, 8))
    assert store.evict([0, 1]) == []  # idempotent
    with pytest.raises(KeyError):
        store.query(0, 7, BETA, strict=True)  # strict sees the loss
    h, eps = store.query(0, 7, BETA, strict=False)
    assert float(np.asarray(h.sizes).sum()) == 6 * N_PER
    assert np.isfinite(eps)


def test_cached_answer_never_served_after_evict():
    """The satellite regression: a query/query_many answer cached before
    evict must never be returned after it (version-keyed invalidation)."""
    store, _ = _store(days=8)
    h_before, _ = store.query(0, 7, BETA)  # populates the LRU
    store.query_many([(0, 7), (2, 5)], BETA)  # and the batched path
    assert float(np.asarray(h_before.sizes).sum()) == 8 * N_PER
    store.evict([0, 1, 2, 3])
    h_after, _ = store.query(0, 7, BETA, strict=False)
    assert float(np.asarray(h_after.sizes).sum()) == 4 * N_PER
    (hm, _), (hm2, _) = store.query_many([(0, 7), (2, 5)], BETA, strict=False)
    assert float(np.asarray(hm.sizes).sum()) == 4 * N_PER
    assert float(np.asarray(hm2.sizes).sum()) == 2 * N_PER  # only 4, 5 left


def test_registry_query_many_never_serves_evicted_cross_tenant():
    """Cross-tenant batched path: warm both tenants' LRUs via the
    registry, evict in one tenant, re-ask the same batch — the evicted
    tenant's answer must be fresh while the untouched tenant's answer is
    bit-identical (still served from its cache)."""
    reg = TenantRegistry(num_buckets=T)
    for name, seed in (("a", 1), ("b", 2)):
        reg.ingest_many(name, _parts(6, seed=seed))
    qs = [("a", 0, 5), ("b", 0, 5)]
    (ha0, _), (hb0, _) = reg.query_many(qs, BETA)
    assert float(np.asarray(ha0.sizes).sum()) == 6 * N_PER
    reg["a"].evict([0, 1, 2])
    res = reg.query_many(qs, BETA, strict=False)
    (ha1, _), (hb1, _) = res
    assert float(np.asarray(ha1.sizes).sum()) == 3 * N_PER  # not the cache
    np.testing.assert_array_equal(
        np.asarray(hb0.sizes), np.asarray(hb1.sizes)
    )


# ---------------------------------------------------------------- policies
def test_ttl_sweeps_on_sync_ingest_against_watermark():
    store = HistogramStore(num_buckets=T, retention=TTL(max_age=3))
    for d, v in _parts(10, seed=3).items():
        store.ingest(d, v)
    assert store.watermark == 9
    assert store.ids() == [6, 7, 8, 9]  # keep watermark-3 .. watermark


def test_sliding_window_sweeps_on_the_async_worker():
    store = HistogramStore(
        num_buckets=T, async_ingest=True, retention=SlidingWindow(4)
    )
    for d, v in _parts(12, seed=4).items():
        store.ingest_async(d, v)
    store.flush()  # flush returning implies the sweep ran on the worker
    assert store.ids() == [8, 9, 10, 11]
    store.close()


def test_memory_budget_bounds_node_floats_and_keeps_newest():
    probe, _ = _store(days=4, seed=5)
    budget = probe.node_floats()  # room for roughly four partitions
    store = HistogramStore(num_buckets=T, retention=MemoryBudget(budget))
    for d, v in _parts(32, seed=5).items():
        store.ingest(d, v)
    assert store.node_floats() <= budget
    assert store.ids(), "budget must not empty the store"
    assert store.ids()[-1] == 31  # newest partition never evicted
    assert store.ids() == sorted(store.ids())  # oldest-first eviction


def test_anyof_unions_policies_and_specs_roundtrip():
    store = HistogramStore(
        num_buckets=T, retention=AnyOf(TTL(5), SlidingWindow(3))
    )
    for d, v in _parts(10, seed=6).items():
        store.ingest(d, v)
    assert store.ids() == [7, 8, 9]  # the window is the tighter policy
    for policy in (
        TTL(7),
        SlidingWindow(4),
        MemoryBudget(12345),
        AnyOf(TTL(2), MemoryBudget(99)),
    ):
        assert policy_from_spec(policy.spec()) == policy
    assert policy_from_spec(None) is None
    with pytest.raises(ValueError):
        TTL(-1)
    with pytest.raises(ValueError):
        SlidingWindow(0)
    with pytest.raises(ValueError):
        MemoryBudget(0)
    with pytest.raises(ValueError):
        AnyOf()
    with pytest.raises(ValueError):
        policy_from_spec({"kind": "bogus"})


# ----------------------------------------------------------- lazy collapse
def test_collapse_rebases_tree_at_lowest_survivor():
    store, parts = _store(days=64, seed=7)
    store.evict(range(60))
    tree = store._tree
    assert store.ids() == [60, 61, 62, 63]
    assert tree.base == 60  # re-rooted: slots no longer grow unboundedly
    assert tree.levels == 2  # minimal depth for 4 leaves
    fresh = HistogramStore(num_buckets=T)
    fresh.ingest_many({d: parts[d] for d in store.ids()})
    assert tree.nodes.keys() == fresh._tree.nodes.keys()
    assert store.node_floats() == fresh.node_floats()


@pytest.mark.parametrize("t_node", [None, "geometric"])
def test_collapse_matches_fresh_build_floats(t_node):
    """Misaligned survivors take the rebase-rebuild path; under geometric
    T_node that is the re-coarsening claim: ancestors are recomputed at
    the shallow tree's resolutions, so the footprint equals (not merely
    approaches) a fresh build over the survivors."""
    parts = _parts(64, seed=8)
    store = HistogramStore(num_buckets=T, T_node=t_node)
    store.ingest_many(parts)
    full = store.node_floats()
    store.evict(range(59))  # survivors 59..63 straddle an alignment
    fresh = HistogramStore(num_buckets=T, T_node=t_node)
    fresh.ingest_many({d: parts[d] for d in range(59, 64)})
    assert store._tree.base == fresh._tree.base == 59
    assert store._tree.levels == fresh._tree.levels
    assert store._tree.nodes.keys() == fresh._tree.nodes.keys()
    assert store.node_floats() == fresh.node_floats() < full
    # eviction-aware eps: the composed bound reflects the collapsed tree
    h1, e1 = store.query(59, 63, BETA)
    h2, e2 = fresh.query(59, 63, BETA)
    np.testing.assert_array_equal(np.asarray(h1.sizes), np.asarray(h2.sizes))
    assert e1 == e2 and np.isfinite(e1)


def test_evict_everything_then_reingest():
    store, _ = _store(days=6, seed=9)
    store.evict(range(6))
    assert store.ids() == []
    assert store._tree.base is None and store._tree.levels == 0
    with pytest.raises(KeyError):
        store.query(0, 5, BETA, strict=False)
    rng = np.random.default_rng(10)
    store.ingest(100, rng.gumbel(size=N_PER).astype(np.float32))
    h, _ = store.query(100, 100, BETA)
    assert float(np.asarray(h.sizes).sum()) == N_PER


# ------------------------------------------------------------- persistence
def test_watermark_and_policy_persist_through_save_load(tmp_path):
    store = HistogramStore(num_buckets=T, retention=TTL(3))
    for d, v in _parts(6, seed=11).items():
        store.ingest(d, v)
    assert store.ids() == [2, 3, 4, 5] and store.watermark == 5
    path = str(tmp_path / "s.npz")
    store.save(path)
    loaded = HistogramStore.load(path)
    assert loaded.watermark == 5
    assert loaded.retention == TTL(3)
    # aging resumes where it stopped: one new partition expires pid 2
    rng = np.random.default_rng(12)
    loaded.ingest(6, rng.gumbel(size=N_PER).astype(np.float32))
    assert loaded.ids() == [3, 4, 5, 6]


def test_watermark_survives_full_eviction_roundtrip(tmp_path):
    store = HistogramStore(num_buckets=T, retention=TTL(2))
    for d, v in _parts(5, seed=13).items():
        store.ingest(d, v)
    store.evict(store.ids())  # operator wipe: nothing retained
    path = str(tmp_path / "s.npz")
    store.save(path)
    loaded = HistogramStore.load(path)
    assert loaded.ids() == [] and loaded.watermark == 4  # not resurrected


def test_registry_persists_budget_retention_and_watermarks(tmp_path):
    reg = TenantRegistry(
        num_buckets=T, retention=SlidingWindow(3), budget=10**9
    )
    reg.ingest_many("a", _parts(6, seed=14))
    assert reg["a"].ids() == [3, 4, 5]
    path = str(tmp_path / "reg.npz")
    reg.save(path)
    loaded = TenantRegistry.load(path)
    assert loaded.budget == 10**9
    assert loaded.retention == SlidingWindow(3)
    assert loaded["a"].retention == SlidingWindow(3)
    assert loaded["a"].watermark == 5
    rng = np.random.default_rng(15)
    loaded.ingest("a", 6, rng.gumbel(size=N_PER).astype(np.float32))
    assert loaded["a"].ids() == [4, 5, 6]  # window keeps sliding


# ---------------------------------------------------------- registry quota
def test_registry_budget_evicts_largest_over_quota_tenant_first():
    probe, _ = _store(days=3, seed=16)
    small_floats = probe.node_floats()
    budget = 4 * small_floats  # quota = 2×small per tenant at 2 tenants
    reg = TenantRegistry(num_buckets=T, budget=budget)
    reg.ingest_many("big", _parts(24, seed=17))
    reg.ingest_many("small", _parts(3, seed=16))
    sizes = reg.node_floats()
    assert sum(sizes.values()) <= budget
    assert reg["small"].ids() == [0, 1, 2]  # under quota: never touched
    big_ids = reg["big"].ids()
    assert big_ids and big_ids[-1] == 23  # newest survives
    assert big_ids == list(range(big_ids[0], 24))  # oldest-first suffix


def test_registry_budget_runs_on_the_pool_worker():
    probe, _ = _store(days=2, seed=18)
    budget = 3 * probe.node_floats()
    reg = TenantRegistry(num_buckets=T, budget=budget, workers=2)
    for name in ("x", "y"):
        for d, v in _parts(10, seed=19).items():
            reg.ingest_async(name, d, v)
    reg.flush()  # flush returning implies the budget sweep ran
    assert sum(reg.node_floats().values()) <= budget
    for name in ("x", "y"):
        assert reg[name].ids() and reg[name].ids()[-1] == 9
    reg.close()


def test_registry_per_tenant_retention_on_the_pool_worker():
    reg = TenantRegistry(num_buckets=T, retention=SlidingWindow(3))
    for d, v in _parts(8, seed=20).items():
        reg.ingest_async("m", d, v)
    reg.flush()
    assert reg["m"].ids() == [5, 6, 7]
    reg.close()


def test_telemetry_hub_forwards_retention():
    from repro.core import TelemetryHub

    hub = TelemetryHub(T=T, retention=SlidingWindow(2))
    rng = np.random.default_rng(21)
    for step in range(5):
        hub.record("loss", step, np.abs(rng.normal(size=64)).astype(np.float32))
    assert hub.registry["loss"].ids() == [3, 4]
    hub.close()
    # silently dropping the knobs would unbound the memory they cap —
    # an explicit registry must carry its own retention/budget
    with pytest.raises(ValueError):
        TelemetryHub(
            T=T, registry=TenantRegistry(num_buckets=T), retention=TTL(1)
        )
    with pytest.raises(ValueError):
        TelemetryHub(T=T, registry=TenantRegistry(num_buckets=T), budget=10)
