"""Test-suite bootstrap.

1. When the real ``hypothesis`` package is absent, register
   ``tests/_propcheck.py`` (a seeded, deterministic, dependency-free stand-in
   for the slice of the hypothesis API the suite uses) as ``hypothesis`` in
   ``sys.modules`` so the five property-test modules collect and run
   unmodified.  Real hypothesis is always preferred when installed.
2. Register the ``slow`` marker backing the fast lane
   (``pytest -m "not slow"``).
3. ``REPRO_LOCK_WITNESS=1`` arms the runtime lock-discipline witness
   (repro.analysis.witness) for the whole run: every acquisition of a
   wrapped core lock asserts the documented rank order, so the entire
   suite — chaos lane included — doubles as a lock-hierarchy check.
   CI's ``fast`` and ``chaos-smoke`` lanes set it (see ANALYSIS.md).
"""
import importlib.util
import os
import sys


def _install_propcheck() -> None:
    if "hypothesis" in sys.modules:
        return
    try:
        import hypothesis  # noqa: F401  (real package wins when present)

        return
    except ModuleNotFoundError:
        pass
    path = os.path.join(os.path.dirname(__file__), "_propcheck.py")
    spec = importlib.util.spec_from_file_location("hypothesis", path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules["hypothesis"] = mod
    spec.loader.exec_module(mod)
    sys.modules["hypothesis.strategies"] = mod.strategies


_install_propcheck()


if os.environ.get("REPRO_LOCK_WITNESS") == "1":
    from repro.analysis import witness as _witness

    _witness.arm()


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: multi-minute model/trainer/system tests "
        '(deselect with -m "not slow" for the fast lane)',
    )


# The seed property-test modules must collect and run UNMODIFIED (they are
# the paper's quality-guarantee suite), but at 60 drawn cases each they take
# minutes — so the fast lane's `slow` mark is attached here at collection
# time instead of in the files.  Tier-1 (`pytest -x -q`) still runs them.
_SLOW_MODULES = {
    "test_bounds",
    "test_hierarchy",
    "test_merge_equivalence",
    "test_quantile_bounds",
}


def pytest_collection_modifyitems(config, items):
    import pytest

    for item in items:
        if item.module.__name__ in _SLOW_MODULES:
            item.add_marker(pytest.mark.slow)
