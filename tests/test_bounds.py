"""Property tests for the paper's Theorems 1 and 2 (hypothesis).

Theorem 1: every bucket of the merged β-bucket histogram holds
``N/β ± ε_max`` values with ``ε_max < 2β/T · (N/β) = 2N/T``.
Theorem 2: the same bound holds for any contiguous range of buckets.

Non-divisible partitions add an integer slack of ``2k`` (module docstring of
core/histogram.py).  Both the *reported* sizes and the *true* value counts
within the output boundaries are checked.
"""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import build_exact, merge_list, merge_histograms_sequential

settings.register_profile("ci", deadline=None, max_examples=60)
settings.load_profile("ci")


@st.composite
def partitions(draw):
    k = draw(st.integers(1, 6))
    T = draw(st.integers(2, 24))
    beta = draw(st.integers(1, T))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    parts = []
    for _ in range(k):
        n = int(rng.integers(T, 500))
        kind = rng.integers(0, 3)
        if kind == 0:
            v = rng.normal(size=n)
        elif kind == 1:
            v = rng.gumbel(size=n) * rng.uniform(0.1, 10)
        else:
            v = rng.integers(0, 50, size=n).astype(float)  # heavy duplicates
        parts.append(v.astype(np.float32))
    return parts, T, beta, kind


@given(partitions())
def test_theorem1_reported_bucket_sizes(args):
    parts, T, beta, _ = args
    hs = [build_exact(jnp.asarray(p), T) for p in parts]
    merged = merge_list(hs, beta)
    n = sum(len(p) for p in parts)
    bound = 2 * n / T + 2 * len(parts)
    sizes = np.asarray(merged.sizes)
    assert np.all(np.abs(sizes - n / beta) <= bound + 1e-3), (
        sizes, n / beta, bound
    )


@given(partitions())
def test_theorem2_reported_range_sizes(args):
    parts, T, beta, _ = args
    hs = [build_exact(jnp.asarray(p), T) for p in parts]
    merged = merge_list(hs, beta)
    n = sum(len(p) for p in parts)
    bound = 2 * n / T + 2 * len(parts)
    cum = np.concatenate([[0.0], np.cumsum(np.asarray(merged.sizes))])
    # range (i..j) sum = cum[j+1]-cum[i]; check all O(β²) ranges
    for i in range(beta):
        for j in range(i, beta):
            m = j - i + 1
            r = cum[j + 1] - cum[i]
            assert abs(r - m * n / beta) <= bound + 1e-3, (i, j, r, bound)


@given(partitions())
def test_theorem1_true_bucket_counts(args):
    """The *actual* number of pooled values inside each output bucket."""
    parts, T, beta, kind = args
    if kind == 2:
        return  # duplicate-heavy integer data makes true counts at tied
        # boundaries ambiguous by the tie mass; covered by reported-size test
    hs = [build_exact(jnp.asarray(p), T) for p in parts]
    merged = merge_list(hs, beta)
    n = sum(len(p) for p in parts)
    pooled = np.sort(np.concatenate(parts))
    b = np.asarray(merged.boundaries, np.float64)
    lo = np.searchsorted(pooled, b[:-1], side="left")
    hi = np.searchsorted(pooled, b[1:], side="left")
    true_sizes = hi - lo
    true_sizes[-1] += np.sum(pooled == b[-1])  # last bucket right-closed
    bound = 2 * n / T + 2 * len(parts)
    assert np.all(np.abs(true_sizes - n / beta) <= bound + 1e-3), (
        true_sizes, n / beta, bound
    )


@given(partitions())
def test_divisible_case_matches_paper_bound_exactly(args):
    """With T | |P_i| (paper's assumption) the pure 2N/T bound holds."""
    parts, T, beta, _ = args
    parts = [p[: (len(p) // T) * T] for p in parts]
    parts = [p for p in parts if len(p) >= T]
    if not parts:
        return
    hs = [build_exact(jnp.asarray(p), T) for p in parts]
    merged = merge_list(hs, beta)
    n = sum(len(p) for p in parts)
    sizes = np.asarray(merged.sizes)
    assert np.all(np.abs(sizes - n / beta) <= 2 * n / T + 1e-3)


@given(partitions())
def test_sequential_reference_same_bounds(args):
    parts, T, beta, _ = args
    hs = [build_exact(jnp.asarray(p), T) for p in parts]
    merged = merge_histograms_sequential(hs, beta)
    n = sum(len(p) for p in parts)
    bound = 2 * n / T + 2 * len(parts)
    sizes = np.asarray(merged.sizes)
    assert np.all(np.abs(sizes - n / beta) <= bound + 1e-3)
