"""Multi-device tests, run in subprocesses so the 8-device XLA flag never
leaks into the main test process (smoke tests must see 1 device)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", code],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_distributed_histogram_matches_local():
    run_with_devices("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import distributed_histogram, build_exact, theoretical_eps_max
from repro.launch.mesh import make_mesh
mesh = make_mesh((4,2), ("data","model"))
rng = np.random.default_rng(0)
N = 8*4000
x = rng.gumbel(size=N).astype(np.float32)
xs = jax.device_put(jnp.asarray(x), NamedSharding(mesh, P(("data","model"))))
h = distributed_histogram(xs, 512, 64, mesh, axis_names=("data","model"))
err = np.abs(np.asarray(h.sizes) - N/64).max()
bound = theoretical_eps_max(N, 512, k=8, exact_inputs=False)
assert err <= bound, (err, bound)
assert float(np.asarray(h.sizes).sum()) == N
print("OK")
""")


@pytest.mark.slow
def test_hierarchical_pod_merge():
    run_with_devices("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import distributed_histogram_hierarchical
from repro.launch.mesh import make_mesh
mesh = make_mesh((2,2,2), ("pod","data","model"))
rng = np.random.default_rng(1)
N = 8*4096
x = rng.normal(size=N).astype(np.float32)
xs = jax.device_put(jnp.asarray(x), NamedSharding(mesh, P(("pod","data","model"))))
h = distributed_histogram_hierarchical(xs, mesh, tile_size=1024, T_tile=256,
      T_device=512, T_pod=512, beta=64, data_axes=("data","model"), pod_axis="pod")
err = np.abs(np.asarray(h.sizes) - N/64).max()
bound = 2*N*(1/256 + 1/512 + 1/512) + 2*(8*4+8+2)
assert err <= bound, (err, bound)
print("OK")
""")


@pytest.mark.slow
def test_sharded_train_step_runs_and_matches_single_device():
    """Same seed, same loss on a 4×2 mesh vs single device (SPMD sanity)."""
    code_tpl = """
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config, smoke
from repro.models import init_model
from repro.optim import OptimizerConfig
from repro.train import make_train_step, make_opt_state
from repro.sharding import Rules
MESH = %r
cfg = smoke(get_config("qwen3-8b"))
key = jax.random.PRNGKey(0)
params, specs = init_model(cfg, key)
opt = make_opt_state(params, OptimizerConfig())
rng = np.random.default_rng(0)
batch = {
  "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32),
  "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32),
  "mask": jnp.ones((8, 32), jnp.float32),
}
if MESH:
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4,2), ("data","model"))
    rules = Rules(cfg, mesh, "train", seq_len=32)
    with mesh:
        step = jax.jit(make_train_step(cfg, OptimizerConfig(), rules))
        p2, o2, m = step(params, opt, batch)
else:
    step = jax.jit(make_train_step(cfg, OptimizerConfig(), None))
    p2, o2, m = step(params, opt, batch)
print("LOSS", float(m["loss"]))
"""
    out_sharded = run_with_devices(code_tpl % True, n=8)
    out_single = run_with_devices(code_tpl % False, n=1)
    l1 = float(out_sharded.split("LOSS")[1].strip().split()[0])
    l2 = float(out_single.split("LOSS")[1].strip().split()[0])
    assert abs(l1 - l2) < 5e-2, (l1, l2)


@pytest.mark.slow
def test_telemetry_quantile_clip_on_mesh():
    run_with_devices("""
import jax, numpy as np, jax.numpy as jnp
from repro.core.telemetry import grad_quantile
from repro.launch.mesh import make_mesh
mesh = make_mesh((8,), ("data",))
rng = np.random.default_rng(2)
grads = {"a": jnp.asarray(rng.normal(size=(512, 16)), jnp.float32),
         "b": jnp.asarray(rng.normal(size=(1024,)), jnp.float32)}
with mesh:
    thr = float(jax.jit(lambda g: grad_quantile(g, 0.99, 256, mesh=mesh,
        axis_names=("data",)))(grads))
allv = np.sort(np.abs(np.concatenate([np.asarray(grads["a"]).ravel(),
                                      np.asarray(grads["b"]).ravel()])))
rank = np.searchsorted(allv, thr) / len(allv)
assert abs(rank - 0.99) < 2/256 + 0.02, (thr, rank)
print("OK")
""")


@pytest.mark.slow
def test_production_mesh_shapes():
    run_with_devices("""
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh(multi_pod=False)
assert dict(m1.shape) == {"data": 16, "model": 16}
m2 = make_production_mesh(multi_pod=True)
assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
print("OK")
""", n=512)
