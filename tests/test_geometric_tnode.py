"""Geometric per-level T_node: depth-independent composed error bound.

With ``HistogramStore(T_node="geometric")`` a level-``l`` tree node carries
``T·2^l`` buckets, so the per-level left-collapse terms form a geometric
series and the composed bound converges to ``ε_total < 4N/T_leaf``
(+ integer slack) regardless of tree depth — versus the uniform mode's
``2N·(depth+1)/T``.  Tests run at depth ≥ 6 (W ≥ 64 partitions) per the
acceptance bar, and cover the bound, resolution doubling, the accuracy win
over uniform, and persistence of the mode.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import HistogramStore

settings.register_profile("ci", deadline=None, max_examples=8)
settings.load_profile("ci")

T = 32
N_PER = 256


def _build(w, seed, t_node):
    rng = np.random.default_rng(seed)
    parts = {}
    for d in range(w):
        kind = d % 3
        if kind == 0:
            v = rng.normal(size=N_PER) * 10
        elif kind == 1:
            v = rng.gumbel(size=N_PER)
        else:
            v = rng.lognormal(-1.0, 0.7, size=N_PER)
        parts[d] = v.astype(np.float32)
    store = HistogramStore(num_buckets=T, T_node=t_node)
    store.ingest_many(parts)
    return store, parts


def _measured_error(h, pooled, beta):
    b = np.asarray(h.boundaries, np.float64)
    true_sizes = (
        np.searchsorted(pooled, b[1:], side="left")
        - np.searchsorted(pooled, b[:-1], side="left")
    ).astype(np.float64)
    true_sizes[-1] += np.sum(pooled == b[-1])
    return np.abs(true_sizes - pooled.size / beta).max()


def _assert_bound_holds(w, seed, windows_extra=4, betas=(8, 16)):
    store, parts = _build(w, seed, "geometric")
    assert store._tree.levels >= 6
    rng = np.random.default_rng(seed + 1)
    windows = [(0, w - 1)] + [
        tuple(sorted((int(rng.integers(0, w)), int(rng.integers(0, w)))))
        for _ in range(windows_extra)
    ]
    for beta in betas:
        for lo, hi in windows:
            h, eps = store.query(lo, hi, beta)
            pooled = np.sort(
                np.concatenate([parts[d] for d in range(lo, hi + 1)])
            )
            assert _measured_error(h, pooled, beta) <= eps + 1e-3


def test_measured_error_within_reported_bound_at_depth6():
    """The acceptance property: at depth ≥ 6, every geometric-mode answer's
    true occupancy error stays within its reported ε_total."""
    _assert_bound_holds(64, 0)


@pytest.mark.slow
@given(st.sampled_from([64, 70, 100]), st.integers(0, 2**31 - 1))
def test_measured_error_within_reported_bound_randomized(w, seed):
    """Randomized widths/seeds/windows of the depth ≥ 6 bound property."""
    _assert_bound_holds(w, seed)


def test_node_resolution_doubles_per_level():
    store, _ = _build(64, 0, "geometric")
    tree = store._tree
    for (lvl, idx), nd in tree.nodes.items():
        if lvl == 0:
            assert nd.num_buckets == T
        elif nd.leaves == 1 << lvl:  # true pair-merged full nodes
            assert nd.num_buckets == T << lvl
    assert tree.node_T(0) == T and tree.node_T(6) == T << 6


def test_geometric_bound_depth_independent_and_beats_uniform():
    """At depth ≥ 6 the geometric full-window bound sits below both the
    uniform mode's bound and the 4N/T series limit plus integer slack."""
    w = 64
    geo, parts = _build(w, 3, "geometric")
    uni, _ = _build(w, 3, None)
    n = w * N_PER
    beta = 16
    hg, eps_geo = geo.query(0, w - 1, beta)
    hu, eps_uni = uni.query(0, w - 1, beta)
    assert eps_geo < eps_uni
    # series limit 4N/T, + one single-level query term 2N/T, + integer
    # slack (+4 per internal merge, +2 per merged node at query time)
    assert eps_geo <= 4 * n / T + 2 * n / T + 4 * w + 2 * 16
    # the uniform bound provably grows with depth; geometric must not
    depth = geo._tree.levels
    assert eps_uni >= 2 * n / T * (depth / 2)
    # and the geometric answer is at least as accurate in practice
    pooled = np.sort(np.concatenate([parts[d] for d in range(w)]))
    assert _measured_error(hg, pooled, beta) <= eps_geo + 1e-3


def test_geometric_incremental_matches_bulk():
    """set_leaf pull-ups and the level-batched bulk build agree bit for bit
    in geometric mode too."""
    rng = np.random.default_rng(9)
    parts = {
        d: rng.normal(size=N_PER).astype(np.float32) for d in range(65)
    }
    bulk = HistogramStore(num_buckets=T, T_node="geometric")
    bulk.ingest_many(parts)
    inc = HistogramStore(num_buckets=T, T_node="geometric")
    for d in sorted(parts):
        inc.ingest(d, parts[d])
    for (a, b) in [(0, 64), (13, 49), (7, 7)]:
        h1, e1 = bulk.query(a, b, beta=8)
        h2, e2 = inc.query(a, b, beta=8)
        np.testing.assert_array_equal(
            np.asarray(h1.boundaries), np.asarray(h2.boundaries)
        )
        np.testing.assert_array_equal(
            np.asarray(h1.sizes), np.asarray(h2.sizes)
        )
        assert e1 == e2


def test_geometric_mode_persists_through_save_load(tmp_path):
    store, _ = _build(64, 5, "geometric")
    path = str(tmp_path / "geo.npz")
    store.save(path)
    loaded = HistogramStore.load(path)
    assert loaded.T_node == "geometric"
    assert loaded._tree.geometric
    assert loaded._tree.nodes.keys() == store._tree.nodes.keys()
    h1, e1 = store.query(0, 63, beta=16)
    h2, e2 = loaded.query(0, 63, beta=16)
    np.testing.assert_array_equal(
        np.asarray(h1.boundaries), np.asarray(h2.boundaries)
    )
    np.testing.assert_array_equal(np.asarray(h1.sizes), np.asarray(h2.sizes))
    assert e1 == e2
    # a post-reload ingest keeps doubling resolution (config survived)
    rng = np.random.default_rng(6)
    loaded.ingest(64, rng.normal(size=N_PER).astype(np.float32))
    assert loaded._tree.node_T(3) == T << 3


def test_unknown_t_node_mode_rejected():
    with pytest.raises(ValueError):
        HistogramStore(num_buckets=8, T_node="exponential")
