"""Durable-ingest suite: WAL unit tests, crash-consistent recovery, the
fsync discipline of ``atomic_savez``, NpzFile fd hygiene, and the
close-vs-poison-retry interleaving of ``IngestPool``.

All crash simulations are in-process: "crash" means dropping the live
object without ``flush``/``save``/``close`` (the in-memory state dies,
the fsynced log survives), and torn writes are literal ``truncate()``s
of the last segment file.  Nothing here sleeps; the interleaving test is
sequenced entirely by events.
"""
import os
import threading

import numpy as np
import pytest

from repro.core import (
    HistogramStore,
    IngestPool,
    SlidingWindow,
    TenantRegistry,
    WriteAheadLog,
)
from repro.core.stream import atomic_savez
from repro.core.workers import PartialBatchFailure
from repro.serve import HistogramService

T = 8
BETA = 16


def _vals(rng, n=32):
    return rng.normal(size=n).astype(np.float32)


def _assert_same_answer(a, b):
    (ha, ea), (hb, eb) = a, b
    assert np.array_equal(np.asarray(ha.boundaries), np.asarray(hb.boundaries))
    assert np.array_equal(np.asarray(ha.sizes), np.asarray(hb.sizes))
    assert ea == eb


# --------------------------------------------------------------------------
# WriteAheadLog unit tests
# --------------------------------------------------------------------------


def test_wal_roundtrip_across_reopen(tmp_path):
    rng = np.random.default_rng(0)
    wal_dir = str(tmp_path / "wal")
    wal = WriteAheadLog(wal_dir)
    recs = {pid: _vals(rng, 16 + pid) for pid in range(5)}
    lsns = [wal.log("tenant-a" if pid % 2 else None, pid, v)
            for pid, v in recs.items()]
    assert lsns == [1, 2, 3, 4, 5]  # dense, monotone
    wal.close()

    re = WriteAheadLog(wal_dir)
    got = re.recovered_records()
    assert [r.lsn for r in got] == lsns
    assert [r.pid for r in got] == list(recs)
    assert [r.tenant for r in got] == [None, "tenant-a", None, "tenant-a", None]
    for r in got:
        assert np.array_equal(r.values, recs[r.pid])
        assert r.values.flags.writeable  # safe to hand to the summarizer
    # a reopened log resumes LSNs after the recovered tail
    assert re.log(None, 99, _vals(rng)) == 6
    re.close()


def test_wal_rotation_and_fresh_segment_per_process(tmp_path):
    rng = np.random.default_rng(1)
    wal_dir = str(tmp_path / "wal")
    wal = WriteAheadLog(wal_dir, segment_bytes=256)  # tiny: force rolls
    for pid in range(6):
        wal.log(None, pid, _vals(rng, 24))
    segs = sorted(p for p in os.listdir(wal_dir) if p.startswith("wal-"))
    assert len(segs) > 1  # rotated
    wal.close()
    # a new process appends to a FRESH segment, never over a torn tail
    re = WriteAheadLog(wal_dir, segment_bytes=256)
    re.log(None, 6, _vals(rng, 24))
    segs2 = sorted(p for p in os.listdir(wal_dir) if p.startswith("wal-"))
    assert len(segs2) == len(segs) + 1
    assert [r.pid for r in WriteAheadLog(wal_dir).recovered_records()] == list(
        range(7)
    )


def test_wal_torn_tail_dropped_valid_prefix_survives(tmp_path):
    rng = np.random.default_rng(2)
    wal_dir = str(tmp_path / "wal")
    wal = WriteAheadLog(wal_dir)
    for pid in range(3):
        wal.log(None, pid, _vals(rng))
    wal.close()
    seg = sorted(tmp_path.glob("wal/wal-*.log"))[-1]
    sz = seg.stat().st_size
    with open(seg, "r+b") as f:
        f.truncate(sz - 11)  # cut into the last record's payload

    re = WriteAheadLog(wal_dir)
    assert [r.pid for r in re.recovered_records()] == [0, 1]
    assert re.torn_records_dropped == 1
    # LSNs resume after the last VALID record — the torn lsn is reused,
    # which is correct: its ack never returned
    assert re.log(None, 9, _vals(rng)) == 3


def test_wal_corrupt_record_stops_segment_scan(tmp_path):
    rng = np.random.default_rng(3)
    wal_dir = str(tmp_path / "wal")
    wal = WriteAheadLog(wal_dir)
    for pid in range(3):
        wal.log(None, pid, _vals(rng))
    wal.close()
    seg = sorted(tmp_path.glob("wal/wal-*.log"))[-1]
    blob = bytearray(seg.read_bytes())
    blob[len(blob) // 2] ^= 0xFF  # flip a payload byte mid-file
    seg.write_bytes(bytes(blob))

    re = WriteAheadLog(wal_dir)
    got = [r.pid for r in re.recovered_records()]
    assert got == [0] or got == [0, 1]  # prefix before the corruption
    assert re.torn_records_dropped == 1


def test_wal_mark_applied_contiguous_prefix():
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        wal = WriteAheadLog(d)
        rng = np.random.default_rng(4)
        for pid in range(4):
            wal.log(None, pid, _vals(rng))
        assert wal.stable_lsn == 0
        wal.mark_applied([2, 3])  # out of order: prefix must NOT advance
        assert wal.stable_lsn == 0
        wal.mark_applied([1])
        assert wal.stable_lsn == 3  # 1 joined → 1..3 contiguous
        wal.mark_applied([4])
        assert wal.stable_lsn == 4
        assert wal.stats()["depth"] == 0
        wal.close()


def test_wal_truncate_keeps_horizon_segment(tmp_path):
    rng = np.random.default_rng(5)
    wal_dir = str(tmp_path / "wal")
    wal = WriteAheadLog(wal_dir, segment_bytes=256)
    for pid in range(6):
        wal.log(None, pid, _vals(rng, 24))
    wal.mark_applied(range(1, 7))
    wal.close()

    re = WriteAheadLog(wal_dir, segment_bytes=256)
    re.mark_applied(range(1, 7))
    removed = re.truncate()
    assert removed  # covered segments reclaimed...
    left = sorted(tmp_path.glob("wal/wal-*.log"))
    assert len(left) >= 1  # ...but the highest one is the LSN anchor
    re.close()
    # the anchor is what lets a NEW process resume instead of reusing
    # LSNs a snapshot already claims to cover
    re2 = WriteAheadLog(wal_dir, segment_bytes=256)
    assert re2.log(None, 6, _vals(rng, 24)) == 7


def test_wal_ensure_position_guards_emptied_dir(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "wal"))
    wal.ensure_position(41)
    assert wal.log(None, 0, np.zeros(4, np.float32)) == 42
    wal.ensure_position(10)  # idempotent: never regresses
    assert wal.log(None, 1, np.zeros(4, np.float32)) == 43
    wal.close()


def test_wal_group_commit_batches_fsyncs(tmp_path, monkeypatch):
    calls = []
    real_fsync = os.fsync

    def recording_fsync(fd):
        calls.append(fd)
        real_fsync(fd)

    monkeypatch.setattr(os, "fsync", recording_fsync)
    rng = np.random.default_rng(6)
    store = HistogramStore(num_buckets=T, wal_dir=str(tmp_path / "wal"))
    store.ingest_many({pid: _vals(rng) for pid in range(8)})
    stats = store.wal_stats()
    assert stats["appends"] == 8
    assert stats["fsyncs"] == 1  # one group commit for the whole batch
    assert stats["synced_lsn"] == 8  # ...and it covered every append
    assert len(calls) == 1
    store.close()


# --------------------------------------------------------------------------
# satellite 1: atomic_savez fsync discipline
# --------------------------------------------------------------------------


def test_atomic_savez_fsyncs_file_then_dir(tmp_path, monkeypatch):
    import stat as stat_mod

    events = []
    real_fsync, real_replace = os.fsync, os.replace

    def recording_fsync(fd):
        kind = "dir" if stat_mod.S_ISDIR(os.fstat(fd).st_mode) else "file"
        events.append(("fsync", kind))
        real_fsync(fd)

    def recording_replace(src, dst):
        events.append(("replace", None))
        real_replace(src, dst)

    monkeypatch.setattr(os, "fsync", recording_fsync)
    monkeypatch.setattr(os, "replace", recording_replace)

    path = str(tmp_path / "out.npz")
    atomic_savez(path, {"k": 1}, {"a": np.arange(4, dtype=np.float32)})
    assert os.path.exists(path)
    # data blocks durable BEFORE the rename, the rename itself AFTER
    assert events == [
        ("fsync", "file"),
        ("replace", None),
        ("fsync", "dir"),
    ]


# --------------------------------------------------------------------------
# satellite 2: no NpzFile fd leaks across load cycles
# --------------------------------------------------------------------------


def _open_fd_count() -> int:
    return len(os.listdir("/proc/self/fd"))


@pytest.mark.skipif(
    not os.path.isdir("/proc/self/fd"), reason="needs procfs"
)
def test_registry_load_cycles_do_not_leak_fds(tmp_path):
    rng = np.random.default_rng(7)
    path = str(tmp_path / "reg.npz")
    reg = TenantRegistry(num_buckets=T)
    for name in ("a", "b"):
        reg.ingest_many(name, {pid: _vals(rng) for pid in range(3)})
    reg.save(path)
    reg.close()

    TenantRegistry.load(path).close()  # warm any lazy module state
    before = _open_fd_count()
    for _ in range(100):
        TenantRegistry.load(path).close()
    after = _open_fd_count()
    # an NpzFile leak costs 1 fd per cycle → +100; allow transient slack
    assert after - before <= 3, f"fd leak: {before} -> {after}"


@pytest.mark.skipif(
    not os.path.isdir("/proc/self/fd"), reason="needs procfs"
)
def test_recover_cycles_do_not_leak_fds(tmp_path):
    rng = np.random.default_rng(8)
    path = str(tmp_path / "reg.npz")
    wal_dir = str(tmp_path / "wal")
    reg = TenantRegistry(num_buckets=T, wal_dir=wal_dir)
    reg.ingest("a", 0, _vals(rng))
    reg.save(path)
    reg.close()

    TenantRegistry.recover(path, wal_dir, num_buckets=T).close()
    before = _open_fd_count()
    for _ in range(50):
        TenantRegistry.recover(path, wal_dir, num_buckets=T).close()
    after = _open_fd_count()
    assert after - before <= 3, f"fd leak: {before} -> {after}"


# --------------------------------------------------------------------------
# satellite 3: close() cannot overtake an in-flight poison retry
# --------------------------------------------------------------------------


def test_close_racing_partial_batch_retry_drops_nothing():
    """Deterministic interleaving (events only, no sleeps):

    1. a blocker item holds the worker while poison+good pile up behind it,
       so they drain into ONE batch;
    2. the batch apply raises ``PartialBatchFailure([poison])``;
    3. the poison retry BLOCKS until ``close()`` has been called from
       another thread — the shutdown sentinel is now queued behind the
       in-flight batch;
    4. the retry fails, the batch finishes, close() joins.

    The non-poisoned item must have applied exactly once, the poison
    error must surface, and nothing may strand in ``pending``.
    """
    applied = []
    batch_entered = threading.Event()
    blocker_release = threading.Event()
    retry_entered = threading.Event()
    close_called = threading.Event()

    def apply_batch(items):
        if items == ["blocker"]:
            batch_entered.set()
            assert blocker_release.wait(10)
            applied.append("blocker")
            return
        if len(items) > 1:  # the drained batch [poison, good]
            applied.extend(i for i in items if i != "poison")
            raise PartialBatchFailure([i for i in items if i == "poison"])
        # the isolated poison retry: hold until close() is in flight
        retry_entered.set()
        assert close_called.wait(10)
        raise RuntimeError("still poison")

    pool = IngestPool(
        apply_batch=apply_batch,
        wrap_error=lambda item, exc: (item, exc),
        workers=1,
    )
    pool.submit("blocker")
    assert batch_entered.wait(10)  # worker is busy: the rest will co-batch
    pool.submit("poison")
    pool.submit("good")
    blocker_release.set()
    assert retry_entered.wait(10)  # worker is inside the poison retry

    closer = threading.Thread(
        target=lambda: (close_called.set(), pool.close())
    )
    closer.start()
    closer.join(10)
    assert not closer.is_alive()

    assert applied == ["blocker", "good"]  # good applied exactly once
    assert pool.pending == 0  # nothing stranded
    errs = pool.errors
    assert len(errs) == 1 and errs[0][0] == "poison"


def test_poison_batch_still_advances_wal_stable_prefix(tmp_path):
    """A poisoned record is marked applied once its retry completes — the
    WAL guards against crashes, not bad data (design note invariant)."""
    wal = WriteAheadLog(str(tmp_path / "wal"))

    def apply_batch(items):
        if any(pid == 1 for pid, _v in items):
            raise PartialBatchFailure(
                [(pid, v) for pid, v in items if pid == 1]
            )

    pool = IngestPool(
        apply_batch=apply_batch,
        wrap_error=lambda item, exc: (item, exc),
        workers=1,
        wal=wal,
        wal_record=lambda item: (None, item[0], item[1]),
    )
    for pid in range(3):
        pool.submit((pid, np.zeros(4, np.float32)))
    errs = pool.drain()
    assert [item[0] for item, _e in errs] == [1]
    assert wal.stable_lsn == 3  # poison lsn did not wedge the prefix
    pool.close()
    wal.close()


# --------------------------------------------------------------------------
# crash-consistent recovery: store and registry
# --------------------------------------------------------------------------


def test_store_crash_before_flush_recovers_bit_identical(tmp_path):
    rng = np.random.default_rng(9)
    wal_dir = str(tmp_path / "wal")
    snap = str(tmp_path / "store.npz")
    data = {pid: _vals(rng, 64) for pid in range(6)}

    st = HistogramStore(num_buckets=T, wal_dir=wal_dir)
    for pid, v in data.items():
        st.ingest_async(pid, v)  # every ack is fsynced...
    del st  # ...then the process dies before flush/save

    rec = HistogramStore.recover(snap, wal_dir, num_buckets=T)
    assert rec.last_recovery["replayed"] == 6
    ref = HistogramStore(num_buckets=T)
    for pid, v in data.items():
        ref.ingest(pid, v)
    _assert_same_answer(rec.query(0, 5, BETA), ref.query(0, 5, BETA))
    rec.close()
    ref.close()


def test_save_truncates_and_reload_replays_nothing(tmp_path):
    rng = np.random.default_rng(10)
    wal_dir = str(tmp_path / "wal")
    snap = str(tmp_path / "store.npz")
    st = HistogramStore(num_buckets=T, wal_dir=wal_dir)
    st.ingest_many({pid: _vals(rng) for pid in range(4)})
    st.save(snap)
    assert st.wal_stats()["stable_lsn"] == 4
    st.close()

    re = HistogramStore.load(snap, wal_dir=wal_dir)
    assert re.last_recovery["replayed"] == 0  # snapshot covers the log
    assert re.ids() == [0, 1, 2, 3]
    re.close()


def test_lsn_horizon_survives_full_truncation(tmp_path):
    """Regression: save() truncating EVERY segment must not let a new
    process restart LSNs below the snapshot's ``wal_stable_lsn`` — the
    next acked ingest would be silently skipped on recovery."""
    rng = np.random.default_rng(11)
    wal_dir = str(tmp_path / "wal")
    snap = str(tmp_path / "store.npz")
    st = HistogramStore(num_buckets=T, wal_dir=wal_dir)
    st.ingest_many({pid: _vals(rng) for pid in range(4)})
    st.save(snap)  # truncation point: the whole log is covered
    st.close()

    st2 = HistogramStore.load(snap, wal_dir=wal_dir)
    st2.ingest(4, _vals(rng))  # must get an lsn ABOVE wal_stable_lsn
    del st2  # crash

    rec = HistogramStore.recover(snap, wal_dir, num_buckets=T)
    assert rec.ids() == [0, 1, 2, 3, 4]
    rec.close()


def test_replay_is_idempotent(tmp_path):
    rng = np.random.default_rng(12)
    wal_dir = str(tmp_path / "wal")
    snap = str(tmp_path / "store.npz")
    st = HistogramStore(num_buckets=T, wal_dir=wal_dir)
    st.ingest_many({pid: _vals(rng) for pid in range(5)})
    del st

    rec1 = HistogramStore.recover(snap, wal_dir, num_buckets=T)
    a1 = rec1.query(0, 4, BETA)
    del rec1  # crash again without saving
    rec2 = HistogramStore.recover(snap, wal_dir, num_buckets=T)
    assert rec2.ids() == [0, 1, 2, 3, 4]
    _assert_same_answer(rec2.query(0, 4, BETA), a1)
    rec2.close()


def test_replay_respects_watermark_and_dedups_pids(tmp_path):
    """Reconciliation rules: a logged pid ≤ the snapshot watermark was
    evicted by retention (never resurrect); a duplicate pid takes the
    LAST append; a pid already present in the snapshot is skipped."""
    rng = np.random.default_rng(13)
    snap = str(tmp_path / "store.npz")
    wal_dir = str(tmp_path / "wal")

    st = HistogramStore(num_buckets=T, retention=SlidingWindow(2))
    st.ingest_many({pid: _vals(rng) for pid in range(4)})
    assert st.ids() == [2, 3]  # 0,1 aged out → watermark 1
    st.save(snap)
    st.close()

    wal = WriteAheadLog(wal_dir)
    wal.log(None, 0, _vals(rng))  # ≤ watermark: must NOT resurrect
    wal.log(None, 3, _vals(rng))  # already present: skipped
    stale = _vals(rng)
    final = _vals(rng)
    wal.log(None, 5, stale)
    wal.log(None, 5, final)  # duplicate pid: last append wins
    wal.close()

    rec = HistogramStore.load(snap, wal_dir=wal_dir)
    # pid 0 not resurrected, pid 3 not double-applied, pid 5 replayed;
    # SlidingWindow(2) swept after replay: exactly the 2 newest remain
    assert rec.ids() == [3, 5]
    ref = HistogramStore(num_buckets=T)
    ref.ingest(5, final)
    _assert_same_answer(rec.query(5, 5, BETA), ref.query(5, 5, BETA))
    rec.close()
    ref.close()


def test_registry_recovery_bit_matches_reference(tmp_path):
    rng = np.random.default_rng(14)
    wal_dir = str(tmp_path / "wal")
    snap = str(tmp_path / "reg.npz")
    data = {
        (t, pid): _vals(rng, 48) for t in ("a", "b") for pid in range(4)
    }

    reg = TenantRegistry(num_buckets=T, wal_dir=wal_dir)
    for (t, pid), v in data.items():
        reg.ingest_async(t, pid, v)
    del reg  # crash with everything still in flight

    rec = TenantRegistry.recover(snap, wal_dir, num_buckets=T)
    ref = TenantRegistry(num_buckets=T)
    for (t, pid), v in data.items():
        ref.ingest(t, pid, v)
    panels = [("a", 0, 3), ("b", 1, 2), ("a", 2, 3)]
    for got, want in zip(
        rec.query_many(panels, BETA), ref.query_many(panels, BETA)
    ):
        _assert_same_answer(got, want)
    rec.close()
    ref.close()


def test_registry_torn_tail_drops_only_last_record(tmp_path):
    rng = np.random.default_rng(15)
    wal_dir = str(tmp_path / "wal")
    snap = str(tmp_path / "reg.npz")
    reg = TenantRegistry(num_buckets=T, wal_dir=wal_dir)
    for pid in range(3):
        reg.ingest("t", pid, _vals(rng, 40))
    del reg
    seg = sorted((tmp_path / "wal").glob("wal-*.log"))[-1]
    with open(seg, "r+b") as f:
        f.truncate(seg.stat().st_size - 13)

    rec = TenantRegistry.recover(snap, wal_dir, num_buckets=T)
    assert rec.last_recovery["torn_records_dropped"] == 1
    assert rec["t"].ids() == [0, 1]
    rec.close()


def test_store_wal_record_without_tenant_rejected_by_registry(tmp_path):
    wal_dir = str(tmp_path / "wal")
    wal = WriteAheadLog(wal_dir)
    wal.log(None, 0, np.zeros(8, np.float32))  # a store's record
    wal.close()
    with pytest.raises(ValueError, match="tenant"):
        TenantRegistry.recover(
            str(tmp_path / "none.npz"), wal_dir, num_buckets=T
        )


# --------------------------------------------------------------------------
# recovery-aware serving startup
# --------------------------------------------------------------------------


def test_histogram_service_recovers_after_kill(tmp_path):
    rng = np.random.default_rng(16)
    data_dir = str(tmp_path / "svc")
    svc = HistogramService(data_dir, num_buckets=T)
    assert svc.recovery["records_scanned"] == 0  # cold start
    for w in range(3):
        svc.record("latency_ms", w, _vals(rng, 64))
    svc.checkpoint()
    svc.record("latency_ms", 3, _vals(rng, 64))  # acked after snapshot
    del svc  # kill -9

    svc2 = HistogramService(data_dir, num_buckets=T)
    assert svc2.recovery["replayed"] == 1  # just the uncovered suffix
    assert svc2.registry["latency_ms"].ids() == [0, 1, 2, 3]
    q = svc2.quantile("latency_ms", 0, 3, 0.95)
    assert np.isfinite(float(np.asarray(q)))
    assert svc2.wal_stats()["depth"] == 0
    svc2.close()


def test_telemetry_hub_wal_passthrough(tmp_path):
    from repro.core.telemetry import TelemetryHub

    hub = TelemetryHub(T=T, wal_dir=str(tmp_path / "wal"))
    hub.record("m", 0, np.ones(16, np.float32))
    stats = hub.wal_stats()
    assert stats is not None and stats["appends"] == 1
    hub.close()
    with pytest.raises(ValueError):
        TelemetryHub(
            T=T,
            registry=TenantRegistry(num_buckets=T),
            wal_dir=str(tmp_path / "wal2"),
        )
