"""Standing-query subscription plane (serve/subscriptions.py).

The push plane's whole contract is "the same answer the pull path gives,
without the polling": every test here pins a piece of that —

* **bit-identity** — across random ingest/evict/subscribe/unsubscribe
  interleavings (shared-arena and per-tenant layouts), every pushed
  update's ``(hist, eps)`` equals a *cold* ``query_many`` pull at the
  same store version (tree caches cleared first, so the comparison
  cannot be satisfied by a shared cache entry);
* **dedup accounting** — N subscribers over W distinct windows cost W
  evaluations and ONE merge dispatch per tick, machine-checked through
  ``merge_dispatches`` and the plane's counters;
* **overflow policies** — coalesce/drop/block behavior and counters;
* **degraded pushes** — a quarantined tenant's subscribers receive the
  last-known-good answer flagged ``degraded=True`` (the
  ``query_many(degraded_ok=True)`` contract), and heal to a fresh push
  once the breaker closes.

Sequencing is entirely event-driven (``plane.flush()`` barriers) — no
sleeps anywhere.
"""
import numpy as np
import pytest

from repro.core import TenantRegistry, faults
from repro.core.resilience import BreakerPolicy
from repro.serve.subscriptions import SubscriptionPlane

T = 8
BETA = 16
N_VALUES = 32


@pytest.fixture(autouse=True)
def _disarm():
    faults.reset()
    yield
    faults.reset()


def _mk(plane_of=SubscriptionPlane, **kw):
    reg = TenantRegistry(num_buckets=T, **kw)
    return reg, plane_of(reg)


def _cold_pull(reg, key):
    """Fresh-from-the-tree answer for one subscription key — the caches
    are cleared first, so a pushed answer cannot match by aliasing."""
    name, lo, hi, beta = key
    reg[name]._tree._cache.clear()
    [ans] = reg.query_many([(name, lo, hi)], beta, strict=False)
    return ans


def _assert_update_matches_pull(reg, update):
    hist, eps = _cold_pull(reg, (update.tenant, update.lo, update.hi,
                                 update.beta))
    assert (update.hist is None) == (hist is None)
    if hist is not None:
        assert np.array_equal(
            np.asarray(update.hist.boundaries), np.asarray(hist.boundaries)
        )
        assert np.array_equal(
            np.asarray(update.hist.sizes), np.asarray(hist.sizes)
        )
    assert update.eps == eps


@pytest.mark.parametrize("shared_arena", [False, True])
def test_push_matches_pull_bit_identical(shared_arena):
    """Random interleavings of ingest / budget-eviction / subscribe /
    unsubscribe: after every flush barrier, each live subscriber's latest
    pushed answer bit-matches a cold pull at the same store version."""
    rng = np.random.default_rng(7 + shared_arena)
    reg, plane = _mk(shared_arena=shared_arena, budget=6000)
    tenants = ["t0", "t1", "t2"]
    live = []  # (sub, last update seen)
    last_up = {}
    next_pid = {t: 0 for t in tenants}
    try:
        for step in range(40):
            op = rng.integers(0, 10)
            t = tenants[int(rng.integers(0, 3))]
            if op < 5:  # ingest (ticks the plane, may evict under budget)
                next_pid[t] += int(rng.integers(1, 3))
                reg.ingest(t, next_pid[t], rng.normal(size=N_VALUES))
            elif op < 7:  # subscribe a random window
                lo = int(rng.integers(0, max(1, next_pid[t])))
                hi = lo + int(rng.integers(0, 8))
                sub = plane.subscribe(t, lo, hi, BETA)
                live.append(sub)
            elif op < 8 and live:  # unsubscribe
                sub = live.pop(int(rng.integers(0, len(live))))
                plane.unsubscribe(sub)
                last_up.pop(id(sub), None)
            elif op < 9:  # explicit eviction sweep
                reg.enforce_budget()
            else:  # barrier + spot-check everything delivered so far
                plane.flush()
                for sub in live:
                    ups = sub.drain()
                    if ups:
                        last_up[id(sub)] = ups[-1]

        plane.flush()  # final barrier: every sub now has a current answer
        for sub in live:
            ups = sub.drain()
            if ups:
                last_up[id(sub)] = ups[-1]
            up = last_up.get(id(sub))
            assert up is not None, f"no update ever pushed for {sub.key}"
            assert not up.degraded  # no faults armed here
            name = sub.key[0]
            assert up.version == reg[name].version
            _assert_update_matches_pull(reg, up)
        # every delivery accounted: accepted pushes minus drains = pending
        stats = plane.stats()
        assert stats["updates_delivered"] > 0
        assert stats["dropped"] == 0  # coalesce default drops nothing
    finally:
        plane.close()
        reg.close()


def test_dedup_shared_windows_one_eval():
    """10 subscribers over 2 distinct windows: one tick costs exactly 2
    window evaluations, 1 merge dispatch, 10 deliveries, 8 saved."""
    reg, plane = _mk()
    try:
        rng = np.random.default_rng(0)
        store = reg.tenant("m")  # store-level: no plane ticks while priming
        store.ingest(0, rng.normal(size=N_VALUES))
        store.ingest(1, rng.normal(size=N_VALUES))
        subs = [plane.subscribe("m", w, w, BETA) for w in (0, 1)
                for _ in range(5)]
        d0 = reg.merge_dispatches
        plane.flush()
        assert reg.merge_dispatches - d0 == 1
        st = plane.stats()
        assert st["windows_evaluated"] == 2
        assert st["eval_batches"] == 1
        assert st["updates_delivered"] == 10
        assert st["dedup_saved"] == 8
        for sub in subs:
            [up] = sub.drain()
            _assert_update_matches_pull(reg, up)
    finally:
        plane.close()
        reg.close()


def test_one_dispatch_per_tick_cross_tenant():
    """Stale windows across MANY tenants still pack into a single
    cross-tenant ``query_many`` merge dispatch per tick."""
    reg, plane = _mk(shared_arena=True)
    try:
        rng = np.random.default_rng(1)
        names = [f"t{i}" for i in range(6)]
        subs = [plane.subscribe(n, 0, 4, BETA) for n in names]
        for n in names:  # store-level ingest: versions move, no ticks
            for pid in range(3):
                reg.tenant(n).ingest(pid, rng.normal(size=N_VALUES))
        for tick in range(3):
            d0 = reg.merge_dispatches
            b0 = plane.stats()["eval_batches"]
            for n in names:
                reg.tenant(n).ingest(3 + tick, rng.normal(size=N_VALUES))
            plane.mark_stale(names)  # ONE tick covering all six tenants
            plane.flush()
            assert reg.merge_dispatches - d0 == 1
            assert plane.stats()["eval_batches"] - b0 == 1
        for sub in subs:
            ups = sub.drain()
            assert ups  # every tick pushed (cap 8 > 3 ticks: none lost)
            _assert_update_matches_pull(reg, ups[-1])
    finally:
        plane.close()
        reg.close()


def test_coalesce_policy_keeps_newest():
    reg, plane = _mk()
    try:
        rng = np.random.default_rng(2)
        sub = plane.subscribe("m", 0, 8, BETA, queue_cap=1)
        for pid in range(3):
            reg.ingest("m", pid, rng.normal(size=N_VALUES))
            plane.flush()
        st = sub.stats()
        assert st["delivered"] == 3
        assert st["coalesced"] == 2  # two older updates displaced
        assert st["pending"] == 1
        [up] = sub.drain()
        assert up.version == reg["m"].version  # the survivor is newest
        _assert_update_matches_pull(reg, up)
    finally:
        plane.close()
        reg.close()


def test_drop_policy_discards_newest_and_counts():
    reg, plane = _mk()
    try:
        rng = np.random.default_rng(3)
        sub = plane.subscribe("m", 0, 8, BETA, policy="drop", queue_cap=1)
        versions = []
        for pid in range(3):
            reg.ingest("m", pid, rng.normal(size=N_VALUES))
            plane.flush()
            versions.append(reg["m"].version)
        st = sub.stats()
        assert st["delivered"] == 1  # only the first made it in
        assert st["dropped"] == 2  # the two newer ones were the casualties
        [up] = sub.drain()
        assert up.version == versions[0]  # oldest kept — drop ≠ coalesce
    finally:
        plane.close()
        reg.close()


def test_block_policy_backpressures_until_consumer_drains():
    """cap=1 block subscriber: the second update waits for the consumer;
    ``get()`` frees the slot and the flush barrier then completes."""
    reg, plane = _mk()
    try:
        rng = np.random.default_rng(4)
        sub = plane.subscribe("m", 0, 8, BETA, policy="block", queue_cap=1)
        reg.ingest("m", 0, rng.normal(size=N_VALUES))
        plane.flush()
        v0 = reg["m"].version
        reg.ingest("m", 1, rng.normal(size=N_VALUES))  # worker now blocks
        first = sub.get(timeout=10.0)  # frees the slot, unblocks delivery
        assert first is not None and first.version == v0
        plane.flush()  # completes only because the consumer drained
        second = sub.get(timeout=10.0)
        assert second is not None
        assert second.version == reg["m"].version
        st = sub.stats()
        assert st["coalesced"] == 0 and st["dropped"] == 0  # nothing lost
        _assert_update_matches_pull(reg, second)
    finally:
        plane.close()
        reg.close()


def test_invalid_policy_and_cap_rejected():
    reg, plane = _mk()
    try:
        with pytest.raises(ValueError):
            plane.subscribe("m", 0, 1, BETA, policy="mystery")
        with pytest.raises(ValueError):
            plane.subscribe("m", 0, 1, BETA, queue_cap=0)
        assert len(plane) == 0
    finally:
        plane.close()
        reg.close()


def test_quarantined_tenant_pushes_degraded_then_heals():
    """Breaker-open tenant: subscribers get the last-known-good answer
    flagged degraded (never advancing their version); breaker closed →
    the next tick re-pushes fresh, bit-matching the pull path."""
    policy = BreakerPolicy(threshold=1, cooldown=0.0, probes=1)
    reg, plane = _mk(breaker=policy)
    try:
        rng = np.random.default_rng(5)
        sub = plane.subscribe("m", 0, 8, BETA)
        reg.ingest("m", 0, rng.normal(size=N_VALUES))
        plane.flush()
        [fresh0] = sub.drain()
        assert not fresh0.degraded

        # trip the breaker: one poisoned ingest (threshold=1)
        with faults.inject("tenant.apply"):
            with pytest.raises(faults.FaultError):
                reg.ingest("m", 1, rng.normal(size=N_VALUES))
        assert reg._breakers["m"].state == "open"
        # the version still moves (store-level ingest bypasses the
        # registry door) — the subscriber is stale AND quarantined
        reg.tenant("m").ingest(2, rng.normal(size=N_VALUES))
        plane.mark_stale(["m"])
        plane.flush()
        # a degraded window is re-pushed on EVERY pass until it heals
        # (tick and flush may coalesce into one pass or run as two)
        degs = sub.drain()
        assert degs and all(u.degraded for u in degs)
        deg = degs[-1]
        assert deg.eps >= fresh0.eps  # honestly widened
        assert plane.stats()["degraded_pushed"] == len(degs)

        # cooldown=0: the next registry ingest closes the breaker, and
        # its tick re-evaluates the still-stale window fresh
        reg.ingest("m", 3, rng.normal(size=N_VALUES))
        plane.flush()
        ups = sub.drain()
        assert ups and not ups[-1].degraded
        assert ups[-1].version == reg["m"].version
        _assert_update_matches_pull(reg, ups[-1])
    finally:
        plane.close()
        reg.close()


def test_registry_close_closes_planes_and_health_surfaces_stats():
    reg, plane = _mk()
    rng = np.random.default_rng(6)
    sub = plane.subscribe("m", 0, 4, BETA)
    reg.ingest("m", 0, rng.normal(size=N_VALUES))
    plane.flush()
    health = reg.health()
    assert health["subscriptions"]["subscriptions"] == 1
    assert health["subscriptions"]["updates_delivered"] == 1
    assert health["subscriptions"]["last_lag_seconds"] >= 0.0
    reg.close()  # closes attached planes
    assert sub.closed
    assert sub.get(timeout=0.0) is not None  # pending update still readable
    with pytest.raises(RuntimeError):
        plane.subscribe("m", 0, 1, BETA)


def test_unsubscribe_stops_deliveries_and_prunes_state():
    reg, plane = _mk()
    try:
        rng = np.random.default_rng(8)
        keep = plane.subscribe("m", 0, 8, BETA)
        gone = plane.subscribe("m", 0, 8, BETA)
        reg.ingest("m", 0, rng.normal(size=N_VALUES))
        plane.flush()
        assert len(gone.drain()) == 1
        plane.unsubscribe(gone)
        assert len(plane) == 1
        reg.ingest("m", 1, rng.normal(size=N_VALUES))
        plane.flush()
        assert gone.pending() == 0  # closed endpoints receive nothing
        assert len(keep.drain()) == 2
        plane.unsubscribe(keep)
        # last subscriber gone: tenant refs and the eval cache both prune
        plane.flush()
        assert plane.stats()["tenants"] == 0
        assert not plane._seen
    finally:
        plane.close()
        reg.close()


def test_service_surface(tmp_path):
    """HistogramService exposes subscribe/unsubscribe; updates ride the
    durable record() path and health() carries the plane stats."""
    from repro.serve import HistogramService

    svc = HistogramService(str(tmp_path / "svc"), num_buckets=T)
    try:
        rng = np.random.default_rng(9)
        sub = svc.subscribe("latency_ms", 0, 4, BETA)
        svc.record("latency_ms", 0, rng.normal(size=N_VALUES))
        svc.subscriptions.flush()
        [up] = sub.drain()
        assert up.tenant == "latency_ms" and not up.degraded
        _assert_update_matches_pull(svc.registry, up)
        assert svc.health()["subscriptions"]["subscriptions"] == 1
        svc.unsubscribe(sub)
        assert sub.closed
    finally:
        svc.close()


def test_hub_surface():
    """TelemetryHub.subscribe reuses one plane across calls."""
    from repro.core.telemetry import TelemetryHub

    hub = TelemetryHub(T=T)
    try:
        rng = np.random.default_rng(10)
        s1 = hub.subscribe("grad_norm", 0, 4, BETA)
        s2 = hub.subscribe("step_ms", 0, 4, BETA)
        assert s1.plane is s2.plane
        hub.record("grad_norm", 0, rng.normal(size=N_VALUES))
        s1.plane.flush()
        [up] = s1.drain()
        assert up.tenant == "grad_norm"
        hub.unsubscribe(s1)
        assert s1.closed and not s2.closed
    finally:
        hub.close()
