"""End-to-end behaviour of the full system (paper framework + LM trainer)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    HistogramStore, build_exact, merge_list, quantile,
    boundary_error, empirical_size_error, sample_histogram,
)


@pytest.mark.slow
def test_paper_end_to_end_log_analytics():
    """The paper's deployment: daily summaries → on-demand interval query,
    merge beats corrected tuple sampling at equal summary size."""
    rng = np.random.default_rng(0)
    days, per_day, T, beta = 14, 20_000, 2032, 254
    store = HistogramStore(num_buckets=T)
    all_vals = []
    for d in range(days):
        v = rng.gumbel(loc=0.05 * d, scale=1 + 0.02 * d, size=per_day)
        v = v.astype(np.float32)
        store.ingest(d, v)
        all_vals.append(v)
    pooled = jnp.asarray(np.concatenate(all_vals))
    exact = build_exact(pooled, beta)

    merged, eps = store.query(0, days - 1, beta)
    mu_s_merge = float(empirical_size_error(merged, pooled))
    mu_b_merge = float(boundary_error(merged, exact))

    tup = sample_histogram(pooled, beta, days * T, jax.random.PRNGKey(0))
    mu_s_tuple = float(empirical_size_error(tup, pooled))
    mu_b_tuple = float(boundary_error(tup, exact))

    # paper Fig. 14-17: merge beats tuple on both errors
    assert mu_s_merge < mu_s_tuple, (mu_s_merge, mu_s_tuple)
    assert mu_b_merge < mu_b_tuple, (mu_b_merge, mu_b_tuple)
    # and the guarantee holds
    n = days * per_day
    assert np.abs(np.asarray(merged.sizes) - n / beta).max() <= eps


def test_p95_monitoring_scenario():
    """95th-percentile latency across servers for any window (paper §1)."""
    rng = np.random.default_rng(1)
    store = HistogramStore(num_buckets=512)
    true = []
    for day in range(30):
        lat = rng.lognormal(-1.5, 0.6, size=5000).astype(np.float32)
        store.ingest(day, lat)
        true.append(lat)
    # christmas-week query
    got = float(store.quantile_query(21, 27, 0.95))
    ref = float(np.quantile(np.concatenate(true[21:28]), 0.95))
    assert got == pytest.approx(ref, rel=0.05)


@pytest.mark.slow
def test_quickstart_module_runs():
    import examples.quickstart as q
    q.main()
