"""Async ingest: background Summarizer with snapshot-consistent queries.

Consistency model under test (core/stream.py module docstring): partitions
enqueued via ``ingest_async`` become visible in whole batches, FIFO, so

* the visible set at any instant is a **prefix** of the enqueue order;
* every concurrent query answers from a consistent snapshot (its mass is an
  exact sum of completely-applied partitions, and its reported ``eps``
  bounds the measured error of exactly that snapshot);
* ``flush()`` makes everything enqueued so far visible and surfaces worker
  errors.

No test here sleeps or depends on scheduler timing: synchronization is only
through ``flush``/``close`` and the store lock.
"""
import numpy as np
import pytest

from repro.core import HistogramStore

N_PER = 256  # equal-size partitions make snapshot mass checks exact
T = 32
BETA = 8


def _partitions(w, seed=0):
    rng = np.random.default_rng(seed)
    return {d: rng.gumbel(size=N_PER).astype(np.float32) for d in range(w)}


def test_flush_makes_all_queued_partitions_visible():
    parts = _partitions(24)
    store = HistogramStore(num_buckets=T, async_ingest=True)
    for d in sorted(parts):
        assert store.ingest(d, parts[d]) is None  # enqueued, not applied
    store.flush()
    h, eps = store.query(0, 23, beta=BETA)
    assert float(np.asarray(h.sizes).sum()) == 24 * N_PER
    store.close()


def test_async_matches_synchronous_store_bitexact():
    """After flush, the async store is indistinguishable from a synchronous
    one fed the same partitions — summaries, answers, and eps."""
    parts = _partitions(16, seed=1)
    sync = HistogramStore(num_buckets=T)
    for d in sorted(parts):
        sync.ingest(d, parts[d])
    async_store = HistogramStore(num_buckets=T, async_ingest=True)
    for d in sorted(parts):
        async_store.ingest(d, parts[d])
    async_store.flush()
    for (a, b) in [(0, 15), (3, 11), (7, 7)]:
        h1, e1 = sync.query(a, b, beta=BETA)
        h2, e2 = async_store.query(a, b, beta=BETA)
        np.testing.assert_array_equal(
            np.asarray(h1.boundaries), np.asarray(h2.boundaries)
        )
        np.testing.assert_array_equal(
            np.asarray(h1.sizes), np.asarray(h2.sizes)
        )
        assert e1 == e2
    async_store.close()


def test_queries_during_concurrent_ingest_see_consistent_prefixes():
    """While the worker drains, every answer is a version-consistent prefix
    snapshot: total mass is a whole multiple of the partition size, and the
    reported eps bounds the measured error of exactly that prefix."""
    W = 32
    parts = _partitions(W, seed=2)
    store = HistogramStore(num_buckets=T, async_ingest=True)
    for d in range(W):
        store.ingest_async(d, parts[d])
    seen_m = []
    for _ in range(10_000):  # bounded: worker finishes independently
        try:
            h, eps = store.query(0, W - 1, beta=BETA, strict=False)
        except KeyError:  # nothing applied yet
            continue
        total = float(np.asarray(h.sizes).sum())
        m = int(round(total / N_PER))
        assert total == m * N_PER  # snapshot = whole partitions only
        assert 1 <= m <= W
        # prefix visibility + eps: the measured error of pooling exactly
        # partitions 0..m-1 must respect this snapshot's reported bound —
        # a non-prefix visible set of the same mass would violate it
        pooled = np.sort(np.concatenate([parts[d] for d in range(m)]))
        b = np.asarray(h.boundaries, np.float64)
        true_sizes = (
            np.searchsorted(pooled, b[1:], side="left")
            - np.searchsorted(pooled, b[:-1], side="left")
        ).astype(np.float64)
        true_sizes[-1] += np.sum(pooled == b[-1])
        assert np.abs(true_sizes - pooled.size / BETA).max() <= eps + 1e-3
        seen_m.append(m)
        if m == W:
            break
    store.flush()
    h, _ = store.query(0, W - 1, beta=BETA)
    assert float(np.asarray(h.sizes).sum()) == W * N_PER
    assert seen_m == sorted(seen_m)  # visibility only ever grows
    store.close()


def test_version_gates_cache_across_async_flushes():
    """Concurrent ingest bumps the version per applied batch, so cached
    answers can never leak across snapshots."""
    store = HistogramStore(num_buckets=T, async_ingest=True)
    parts = _partitions(8, seed=3)
    for d in range(4):
        store.ingest_async(d, parts[d])
    store.flush()
    v1 = store.version
    h1, _ = store.query(0, 7, beta=BETA, strict=False)
    n1 = float(np.asarray(h1.sizes).sum())
    for d in range(4, 8):
        store.ingest_async(d, parts[d])
    store.flush()
    assert store.version > v1
    h2, _ = store.query(0, 7, beta=BETA, strict=False)
    assert float(np.asarray(h2.sizes).sum()) == 8 * N_PER > n1
    store.close()


def test_empty_partition_fails_synchronously_not_in_worker():
    """Input validation happens on the caller thread: a bad partition is
    rejected before it can poison a background batch."""
    store = HistogramStore(num_buckets=T, async_ingest=True)
    with pytest.raises(ValueError):
        store.ingest_async(0, np.asarray([], np.float32))
    store.flush()  # nothing enqueued, nothing pending, no error
    store.close()


def test_worker_error_isolates_poison_and_spares_cobatched_partitions():
    """A partition that fails inside the worker must not drop the valid
    partitions drained into the same batch: the batch is retried row by
    row, survivors apply, and flush() reports exactly the poison pids."""
    parts = _partitions(8, seed=4)
    store = HistogramStore(num_buckets=T, async_ingest=True)
    orig = store._summarize_batch

    def failing(batch):  # pid 3 is poison no matter how it is batched
        if 3 in batch:
            raise RuntimeError("boom at pid 3")
        return orig(batch)

    store._summarize_batch = failing
    for d in sorted(parts):  # all 8 likely drain into one batch
        store.ingest_async(d, parts[d])
    with pytest.raises(RuntimeError) as ei:
        store.flush()
    assert "partition 3" in str(ei.value)
    # every valid co-batched partition survived and is visible
    assert sorted(store.ids()) == [0, 1, 2, 4, 5, 6, 7]
    h, _ = store.query(0, 7, beta=BETA, strict=False)
    assert float(np.asarray(h.sizes).sum()) == 7 * N_PER
    # the worker is still alive, the error list was cleared by flush
    store._summarize_batch = orig
    store.ingest_async(3, parts[3])
    store.flush()
    h, _ = store.query(0, 7, beta=BETA)
    assert float(np.asarray(h.sizes).sum()) == 8 * N_PER
    store.close()


def test_close_drains_then_stops():
    parts = _partitions(6, seed=5)
    store = HistogramStore(num_buckets=T, async_ingest=True)
    for d in sorted(parts):
        store.ingest(d, parts[d])
    store.close()  # must drain everything enqueued before the sentinel
    h, _ = store.query(0, 5, beta=BETA)
    assert float(np.asarray(h.sizes).sum()) == 6 * N_PER
    # ingest_async after close restarts a worker transparently
    store.ingest_async(6, parts[0])
    store.flush()
    assert 6 in store.summaries
    store.close()
