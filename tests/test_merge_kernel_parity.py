"""Golden parity: the fused Pallas merge kernel vs the vectorized merge vs
the sequential paper-Algorithm-1 oracle, on fixed seeds.

Complements the randomized equivalence suite (test_merge_equivalence) with a
deterministic golden set that pins the edge cases down by construction:

* flat boundary count ``k(T+1)`` not a power of two → the kernel's
  pad-to-power-of-two with ``+inf`` boundaries / zero mass is exercised on
  every case where ``k(T+1)`` isn't already ``2^m`` (and one case where it
  is, so the no-pad path stays covered);
* duplicate boundaries (heavily tied integer data), where stable-sort tie
  handling and the left-collapse cumulative both have to agree bit-for-bit
  with the oracle;
* degenerate shapes: a single source (k=1), a single output bucket (β=1),
  and β=T.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Histogram,
    build_exact,
    merge,
    merge_histograms_sequential,
)
from repro.kernels import merge_pallas

# (seed, k, T, beta, duplicate-heavy)
GOLDEN = [
    (0, 1, 4, 2, False),  # k(T+1)=5  → padded to 8
    (1, 3, 16, 16, False),  # k(T+1)=51 → padded to 64; beta == T
    (2, 7, 15, 5, False),  # k(T+1)=112 → padded to 128
    (3, 2, 8, 1, False),  # beta == 1 (degenerate single bucket)
    (4, 3, 41, 12, True),  # k(T+1)=126 → padded; heavy boundary ties
    (5, 5, 12, 7, True),  # ties + uneven partition sizes
    (6, 1, 7, 7, True),  # k(T+1)=8 is already a power of two (no pad)
    (7, 4, 20, 19, False),  # k(T+1)=84 → padded to 128
]


def _make_histograms(seed: int, k: int, T: int, dup: bool):
    rng = np.random.default_rng(seed)
    hs = []
    for _ in range(k):
        n = int(rng.integers(T, 400))
        if dup:  # few distinct values → many tied boundaries
            v = rng.integers(0, 8, size=n).astype(np.float32)
        else:
            v = (rng.normal(size=n) * 5).astype(np.float32)
        hs.append(build_exact(jnp.asarray(v), T))
    return hs


@pytest.mark.parametrize("seed,k,T,beta,dup", GOLDEN)
def test_pallas_merge_matches_vectorized_and_sequential(seed, k, T, beta, dup):
    hs = _make_histograms(seed, k, T, dup)
    stacked = Histogram(
        jnp.stack([h.boundaries for h in hs]),
        jnp.stack([h.sizes for h in hs]),
    )
    bo, so = merge_pallas(stacked.boundaries, stacked.sizes, beta)
    n = float(np.asarray(stacked.sizes).sum())

    hv = merge(stacked, beta)  # vectorized rank-select (production path)
    hq = merge_histograms_sequential(hs, beta)  # paper Algorithm 1 oracle

    for got_b, got_s, src in [
        (bo, so, "pallas-vs-"),
        (np.asarray(hv.boundaries), np.asarray(hv.sizes), "vector-vs-"),
    ]:
        np.testing.assert_allclose(
            np.asarray(got_b),
            np.asarray(hq.boundaries),
            rtol=1e-6,
            err_msg=src + "sequential boundaries",
        )
        np.testing.assert_allclose(
            np.asarray(got_s),
            np.asarray(hq.sizes),
            atol=1e-2,
            err_msg=src + "sequential sizes",
        )
    # mass conservation through the kernel's +inf/zero-mass padding
    assert float(np.asarray(so).sum()) == pytest.approx(n, abs=1e-2)
    assert np.all(np.isfinite(np.asarray(bo)))


def test_pallas_merge_padded_tail_carries_no_mass():
    """A case engineered so the pad region is large (k(T+1)=18 → 32): the
    padded +inf boundaries must never leak into boundaries or sizes."""
    hs = _make_histograms(11, 2, 8, True)
    stacked = Histogram(
        jnp.stack([h.boundaries for h in hs]),
        jnp.stack([h.sizes for h in hs]),
    )
    bo, so = merge_pallas(stacked.boundaries, stacked.sizes, 4)
    hq = merge_histograms_sequential(hs, 4)
    np.testing.assert_allclose(np.asarray(bo), np.asarray(hq.boundaries))
    np.testing.assert_allclose(np.asarray(so), np.asarray(hq.sizes), atol=1e-2)
    assert float(np.asarray(bo)[-1]) == float(
        np.asarray(stacked.boundaries).max()
    )


def test_pallas_merge_duplicate_boundary_mass_alignment():
    """All-tied sources: every boundary equal; the merge must put all mass in
    the final bucket span without NaNs from the masked +inf padding."""
    b = jnp.asarray(np.full((2, 5), 3.0, np.float32))
    s = jnp.asarray(np.full((2, 4), 10.0, np.float32))
    bo, so = merge_pallas(b, s, 3)
    assert np.all(np.isfinite(np.asarray(bo)))
    assert float(np.asarray(so).sum()) == pytest.approx(80.0)
    want = merge(Histogram(b, s), 3)
    np.testing.assert_allclose(np.asarray(bo), np.asarray(want.boundaries))
    np.testing.assert_allclose(np.asarray(so), np.asarray(want.sizes))
