"""Seeded, deterministic, dependency-free stand-in for `hypothesis`.

The seed property suites (test_bounds, test_hierarchy, test_merge_equivalence,
test_quantile_bounds, test_interval_tree) use a small slice of the hypothesis
API: ``@given``, ``settings`` profiles, and the ``integers`` / ``floats`` /
``sampled_from`` / ``composite`` strategies.  This module implements exactly
that slice on top of ``numpy.random.default_rng`` so the quality-guarantee
tests run on machines without hypothesis installed.

``tests/conftest.py`` registers this module as ``hypothesis`` in
``sys.modules`` *only when the real package is absent* — real hypothesis is
always preferred when installed.

Determinism: every test draws its cases from a PRNG seeded by the test's
qualified name and the case index, so failures reproduce across runs and
machines and do not depend on test execution order.
"""
from __future__ import annotations

import hashlib
import types

import numpy as np


class UnsatisfiedAssumption(Exception):
    """Raised by :func:`assume` to discard the current case."""


def assume(condition) -> bool:
    if not condition:
        raise UnsatisfiedAssumption()
    return True


class SearchStrategy:
    """A strategy is just a sampler: rng -> value."""

    def __init__(self, sample):
        self._sample = sample

    def example(self, rng: np.random.Generator):
        return self._sample(rng)

    def map(self, fn) -> "SearchStrategy":
        return SearchStrategy(lambda rng: fn(self._sample(rng)))

    def filter(self, pred) -> "SearchStrategy":
        def sample(rng):
            for _ in range(100):
                v = self._sample(rng)
                if pred(v):
                    return v
            raise UnsatisfiedAssumption()

        return SearchStrategy(sample)


def _integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: int(rng.integers(min_value, max_value + 1))
    )


def _floats(min_value: float, max_value: float, **_kw) -> SearchStrategy:
    return SearchStrategy(lambda rng: float(rng.uniform(min_value, max_value)))


def _booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.integers(0, 2)))


def _sampled_from(elements) -> SearchStrategy:
    elems = list(elements)
    return SearchStrategy(lambda rng: elems[int(rng.integers(0, len(elems)))])


def _lists(elem: SearchStrategy, min_size=0, max_size=10) -> SearchStrategy:
    def sample(rng):
        k = int(rng.integers(min_size, max_size + 1))
        return [elem.example(rng) for _ in range(k)]

    return SearchStrategy(sample)


def _composite(fn):
    """``@st.composite`` — ``fn(draw, *args)`` becomes a strategy factory."""

    def make(*args, **kwargs):
        def sample(rng):
            return fn(lambda strat: strat.example(rng), *args, **kwargs)

        return SearchStrategy(sample)

    make.__name__ = getattr(fn, "__name__", "composite")
    return make


# the `hypothesis.strategies` namespace (registered in sys.modules by conftest)
strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.floats = _floats
strategies.booleans = _booleans
strategies.sampled_from = _sampled_from
strategies.lists = _lists
strategies.composite = _composite
strategies.SearchStrategy = SearchStrategy


class settings:
    """Profile registry — only ``max_examples`` is honoured; ``deadline`` and
    other keywords are accepted and ignored (we never time tests out)."""

    _profiles: dict[str, dict] = {"default": {"max_examples": 50}}
    _current: dict = _profiles["default"]

    def __init__(self, **kwargs):
        self._kwargs = kwargs

    def __call__(self, test):  # used as a decorator: @settings(...)
        test._propcheck_settings = self._kwargs
        return test

    @classmethod
    def register_profile(cls, name: str, **kwargs) -> None:
        cls._profiles[name] = kwargs

    @classmethod
    def load_profile(cls, name: str) -> None:
        cls._current = cls._profiles[name]


class HealthCheck:  # accepted for API compatibility, never enforced
    all = staticmethod(lambda: [])
    too_slow = data_too_large = filter_too_much = None


def given(*arg_strategies, **kw_strategies):
    """Deterministic ``@given``: run the test on ``max_examples`` drawn cases.

    The wrapper takes no parameters (mirroring real hypothesis, whose wrapper
    supplies all strategy-bound arguments itself) so pytest does not mistake
    the test's argument names for fixtures.
    """

    def decorate(test):
        def run():
            overrides = getattr(test, "_propcheck_settings", {})
            n = overrides.get(
                "max_examples", settings._current.get("max_examples", 50)
            )
            seed = int.from_bytes(
                hashlib.sha256(
                    f"{test.__module__}.{test.__qualname__}".encode()
                ).digest()[:4],
                "big",
            )
            for case in range(n):
                rng = np.random.default_rng((seed, case))
                args, kwargs = (), {}
                try:
                    args = [s.example(rng) for s in arg_strategies]
                    kwargs = {
                        k: s.example(rng) for k, s in kw_strategies.items()
                    }
                    test(*args, **kwargs)
                except UnsatisfiedAssumption:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (case {case} of {n}, seed "
                        f"{seed}): args={args!r} kwargs={kwargs!r}: {e}"
                    ) from e

        run.__name__ = test.__name__
        run.__doc__ = test.__doc__
        run.__module__ = test.__module__
        run.__qualname__ = test.__qualname__
        run.is_hypothesis_test = True
        return run

    return decorate


def example(*_a, **_k):  # @example pins are simply ignored
    return lambda test: example and test


def note(_msg) -> None:
    pass
