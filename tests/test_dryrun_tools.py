"""Units for the dry-run costing machinery (no 512-device init needed)."""
import dataclasses

import pytest

from repro.configs import SHAPES, get_config
from repro.launch import dryrun


def test_parse_collective_bytes_synthetic_hlo():
    hlo = """
  %ag = bf16[128,256]{1,0} all-gather(%x), replica_groups=...
  %ar.1 = f32[1024]{0} all-reduce(%y), to_apply=%sum
  %rs = (f32[64]{0}, f32[64]{0}) reduce-scatter(%a, %b), dimensions={0}
  %cp = bf16[32]{0} collective-permute(%z), source_target_pairs=...
  %ag2 = bf16[8]{0} all-gather-start(%w)
  %agd = bf16[8]{0} all-gather-done(%ag2)
  %notacoll = f32[4]{0} add(%p, %q)
"""
    out = dryrun.parse_collective_bytes(hlo)
    assert out["all-gather"] == 128 * 256 * 2 + 8 * 2  # start counted, done not
    assert out["all-reduce"] == 1024 * 4
    assert out["reduce-scatter"] == 2 * 64 * 4
    assert out["collective-permute"] == 32 * 2
    assert out["n_all-gather"] == 2 and out["n_all-reduce"] == 1


def test_costing_config_collapses_loops():
    cfg = get_config("gemma2-9b")
    shape = SHAPES["train_4k"]
    c1 = dryrun.costing_config(cfg, shape, 1)
    assert c1.repeats == 1 and c1.scan_unroll == 1
    assert c1.attn_q_chunk == shape.seq_len
    assert c1.loss_chunk == shape.seq_len
    c2 = dryrun.costing_config(cfg, shape, 2)
    assert c2.repeats == 2 and c2.scan_unroll == 2


def test_costing_config_encoder_scaling():
    cfg = get_config("whisper-medium")
    c2 = dryrun.costing_config(cfg, SHAPES["train_4k"], 2)
    assert c2.encoder_layers == 2  # enc scales with r so the marginal is exact


def test_model_flops_train_vs_decode():
    cfg = get_config("deepseek-7b")
    train = dryrun._model_flops(cfg, SHAPES["train_4k"])
    assert train == pytest.approx(6 * cfg.param_count() * 256 * 4096, rel=1e-6)
    dec = dryrun._model_flops(cfg, SHAPES["decode_32k"])
    assert dec == pytest.approx(2 * cfg.param_count() * 128, rel=1e-6)


def test_model_flops_moe_uses_active():
    cfg = get_config("llama4-maverick-400b-a17b")
    f = dryrun._model_flops(cfg, SHAPES["train_4k"])
    assert f == pytest.approx(
        6 * cfg.active_param_count() * 256 * 4096, rel=1e-6
    )
    assert cfg.active_param_count() < 0.05 * cfg.param_count()


def test_shape_bytes_tuple_shapes():
    assert dryrun._shape_bytes("(bf16[2,2], f32[3])") == 2 * 2 * 2 + 3 * 4
    assert dryrun._shape_bytes("pred[7]") == 7
    assert dryrun._shape_bytes("u32[]") == 4  # a scalar still moves 4 bytes
