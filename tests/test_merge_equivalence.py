"""The vectorized rank-select merge is bit-identical to paper Algorithm 1."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (
    Histogram,
    build_exact,
    merge,
    merge_histograms_sequential,
)
from repro.kernels import merge_pallas

settings.register_profile("ci", deadline=None, max_examples=60)
settings.load_profile("ci")


@st.composite
def stacked_histograms(draw):
    k = draw(st.integers(1, 5))
    T = draw(st.integers(2, 16))
    beta = draw(st.integers(1, T))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    hs = []
    for _ in range(k):
        n = int(rng.integers(T, 300))
        dup = rng.integers(0, 2)
        v = (
            rng.integers(0, 20, size=n).astype(np.float32)
            if dup
            else rng.normal(size=n).astype(np.float32) * 5
        )
        hs.append(build_exact(jnp.asarray(v), T))
    return hs, beta


@given(stacked_histograms())
def test_vectorized_equals_sequential(args):
    hs, beta = args
    stacked = Histogram(
        jnp.stack([h.boundaries for h in hs]),
        jnp.stack([h.sizes for h in hs]),
    )
    hv = merge(stacked, beta)
    hq = merge_histograms_sequential(hs, beta)
    np.testing.assert_allclose(
        np.asarray(hv.boundaries), np.asarray(hq.boundaries)
    )
    np.testing.assert_allclose(
        np.asarray(hv.sizes), np.asarray(hq.sizes), atol=1e-2
    )


@given(stacked_histograms())
def test_pallas_kernel_equals_sequential(args):
    hs, beta = args
    stacked = Histogram(
        jnp.stack([h.boundaries for h in hs]),
        jnp.stack([h.sizes for h in hs]),
    )
    bo, so = merge_pallas(stacked.boundaries, stacked.sizes, beta)
    hq = merge_histograms_sequential(hs, beta)
    np.testing.assert_allclose(np.asarray(bo), np.asarray(hq.boundaries))
    np.testing.assert_allclose(np.asarray(so), np.asarray(hq.sizes), atol=1e-2)
