"""Per-kernel shape/dtype sweeps against the pure-jnp ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Histogram, build_exact, merge
from repro.kernels import (
    bucket_sizes_pallas,
    cumulative_counts_pallas,
    merge_pallas,
    sort_kv_pallas,
    sort_tiles_pallas,
    summarize_pallas,
)
from repro.kernels import ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("n", [100, 8192, 50_000])
@pytest.mark.parametrize("T", [4, 64, 257])
@pytest.mark.parametrize("dtype", [np.float32, np.float64, np.int32])
def test_bucket_count_sweep(n, T, dtype):
    if dtype == np.int32:
        x = RNG.integers(-100, 100, size=n).astype(dtype)
    else:
        x = (RNG.normal(size=n) * 10).astype(dtype)
    b = np.sort(RNG.normal(size=T + 1) * 10).astype(np.float32)
    got = cumulative_counts_pallas(jnp.asarray(x), jnp.asarray(b))
    want = ref.cumulative_counts_ref(jnp.asarray(x), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block_rows", [8, 64])
def test_bucket_count_block_shapes(block_rows):
    x = RNG.normal(size=5000).astype(np.float32)
    b = np.sort(RNG.normal(size=33)).astype(np.float32)
    got = cumulative_counts_pallas(
        jnp.asarray(x), jnp.asarray(b), block_rows=block_rows
    )
    want = ref.cumulative_counts_ref(jnp.asarray(x), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_bucket_sizes_sum_to_n():
    x = RNG.gumbel(size=20_000).astype(np.float32)
    h = build_exact(jnp.asarray(x), 64)
    sizes = bucket_sizes_pallas(jnp.asarray(x), h.boundaries)
    assert float(np.asarray(sizes).sum()) == 20_000
    np.testing.assert_allclose(np.asarray(sizes), np.asarray(h.sizes))


@pytest.mark.parametrize("tiles,tile_len", [(1, 128), (4, 1024), (3, 4096)])
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_tile_sort_sweep(tiles, tile_len, dtype):
    if dtype == np.int32:
        x = RNG.integers(-1000, 1000, size=(tiles, tile_len)).astype(dtype)
    else:
        x = RNG.normal(size=(tiles, tile_len)).astype(dtype)
    got = sort_tiles_pallas(jnp.asarray(x))
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.sort_tiles_ref(jnp.asarray(x)))
    )


def test_tile_sort_with_duplicates_and_extremes():
    x = np.concatenate([
        np.full(100, 3.0), np.full(50, -7.0),
        RNG.integers(0, 5, 874).astype(np.float32),
    ]).astype(np.float32)[None, :1024]
    got = sort_tiles_pallas(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.sort(x, -1))


@pytest.mark.parametrize(
    "tile_len",
    [256, 512, pytest.param(2048, marks=pytest.mark.slow)],
)
def test_kv_sort_preserves_payload_multiset(tile_len):
    keys = RNG.integers(0, 7, size=(2, tile_len)).astype(np.float32)
    vals = RNG.normal(size=(2, tile_len)).astype(np.float32)
    gk, gv = sort_kv_pallas(jnp.asarray(keys), jnp.asarray(vals))
    gk, gv = np.asarray(gk), np.asarray(gv)
    np.testing.assert_allclose(gk, np.sort(keys, -1))
    for r in range(2):
        # per-key payload multisets survive (ties handled correctly)
        for kk in np.unique(keys[r]):
            np.testing.assert_allclose(
                np.sort(gv[r][gk[r] == kk]), np.sort(vals[r][keys[r] == kk])
            )


@pytest.mark.parametrize("k,T,beta", [(1, 4, 2), (3, 16, 16), (7, 18, 5), (2, 8, 1)])
def test_merge_kernel_vs_core(k, T, beta):
    hs = [
        build_exact(
            jnp.asarray(RNG.normal(size=int(RNG.integers(T, 400))).astype(np.float32)),
            T,
        )
        for _ in range(k)
    ]
    stacked = Histogram(
        jnp.stack([h.boundaries for h in hs]),
        jnp.stack([h.sizes for h in hs]),
    )
    bo, so = merge_pallas(stacked.boundaries, stacked.sizes, beta)
    want = merge(stacked, beta)
    np.testing.assert_allclose(np.asarray(bo), np.asarray(want.boundaries), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(so), np.asarray(want.sizes), atol=1e-2)


@pytest.mark.slow
@pytest.mark.parametrize("tile_len,T_tile", [(1024, 64), (4096, 256)])
def test_summarize_pipeline_bound(tile_len, T_tile):
    n_tiles = 8
    x = RNG.gumbel(size=n_tiles * tile_len).astype(np.float32)
    h = summarize_pallas(
        jnp.asarray(x), tile_len=tile_len, T_tile=T_tile, T_out=T_tile
    )
    n = x.size
    err = np.abs(np.asarray(h.sizes) - n / T_tile).max()
    assert err <= 2 * n / T_tile + 2 * n_tiles
    assert float(np.asarray(h.sizes).sum()) == pytest.approx(n)
