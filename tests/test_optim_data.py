"""Optimizer, compression, and data-pipeline behaviours."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import LengthBucketer, SyntheticLM
from repro.optim import (
    CompressionConfig,
    OptimizerConfig,
    adamw_update,
    clip_grads,
    compress_grads,
    init_opt_state,
    init_residual,
    lr_schedule,
)


def test_adamw_converges_on_quadratic():
    cfg = OptimizerConfig(peak_lr=0.1, warmup_steps=1, decay_steps=200,
                          weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_opt_state(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, cfg)
    assert float(loss(params)) < 1e-2


def test_lr_schedule_shape():
    cfg = OptimizerConfig(peak_lr=1.0, warmup_steps=10, decay_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.float32(s))) for s in range(0, 101, 10)]
    assert lrs[1] == pytest.approx(1.0)  # end of warmup
    assert max(lrs) <= 1.0 and lrs[-1] == pytest.approx(0.1, abs=1e-6)


def test_global_norm_clip():
    cfg = OptimizerConfig(clip_mode="global_norm", clip_value=1.0)
    g = {"a": jnp.full((100,), 10.0)}
    clipped, m = clip_grads(g, cfg)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-3)


def test_quantile_clip_threshold_rank():
    cfg = OptimizerConfig(clip_mode="quantile", clip_q=0.99, clip_hist_T=512)
    rng = np.random.default_rng(0)
    g = {"a": jnp.asarray(rng.normal(size=20000), jnp.float32)}
    clipped, m = clip_grads(g, cfg)
    thr = float(m["clip_threshold"])
    frac_above = float(np.mean(np.abs(np.asarray(g["a"])) > thr))
    assert abs(frac_above - 0.01) < 2 / 512 + 0.005
    assert float(jnp.max(jnp.abs(clipped["a"]))) <= thr * 1.0001


def test_compression_error_feedback():
    """Sparsified + residual == original accumulated gradient (lossless EF)."""
    ccfg = CompressionConfig(enabled=True, rho=0.05, hist_T=512)
    rng = np.random.default_rng(1)
    g = {"a": jnp.asarray(rng.normal(size=8192), jnp.float32)}
    resid = init_residual(g)
    sparse, new_resid, m = compress_grads(g, resid, ccfg)
    np.testing.assert_allclose(
        np.asarray(sparse["a"]) + np.asarray(new_resid["a"]),
        np.asarray(g["a"]), rtol=1e-6,
    )
    kept = float(m["compress_kept_fraction"])
    assert abs(kept - 0.05) < 2 / 512 + 0.01
    # survivors are exactly the largest-magnitude entries (within rank bound)
    thr = float(m["compress_threshold"])
    assert np.all(np.abs(np.asarray(sparse["a"]))[np.asarray(sparse["a"]) != 0] >= thr)


def test_synthetic_data_deterministic_resume():
    d1 = SyntheticLM(vocab_size=1000, seq_len=64, global_batch=4, seed=3)
    d2 = SyntheticLM(vocab_size=1000, seq_len=64, global_batch=4, seed=3)
    for step in (0, 7, 123):
        b1, b2 = d1.batch_at(step), d2.batch_at(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(
        d1.batch_at(0)["tokens"], d1.batch_at(1)["tokens"]
    )


def test_length_bucketer_balances_counts():
    rng = np.random.default_rng(4)
    shards = [rng.lognormal(5.5, 1.0, size=4000).astype(np.float32)
              for _ in range(4)]
    b = LengthBucketer(num_buckets=8, summary_T=256).fit(shards)
    allv = np.concatenate(shards)
    counts = np.bincount(b.assign(allv), minlength=8)
    # equi-depth: every bucket within the paper bound of N/8
    n = len(allv)
    assert np.abs(counts - n / 8).max() <= 2 * n / 256 + 2 * 4 + 8
    rep = b.bucket_report(allv)
    assert rep["pad_waste_bucketed"] < rep["pad_waste_unbucketed"]


def test_bucketer_report_monotone_buckets():
    rng = np.random.default_rng(5)
    lens = rng.lognormal(5.0, 0.8, size=10000).astype(np.float32)
    b = LengthBucketer(num_buckets=4, summary_T=128).fit([lens])
    assert np.all(np.diff(b.boundaries_) >= 0)
